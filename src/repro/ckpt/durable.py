"""Durable writer for the streaming SCC service: WAL + async snapshots.

``SCCService`` keeps the whole committed history in process memory; a
crash loses every acknowledged generation.  :class:`DurableService` is
the durable writer role of the replication story (docs/SERVICE_API.md
§Durability): every update chunk is appended to a segmented, CRC-framed
write-ahead log (:mod:`repro.ckpt.oplog`) and fsynced *before* it is
applied, and the committed state is checkpointed periodically off the
apply path via :mod:`repro.ckpt.checkpoint` graph snapshots.  Recovery
(:meth:`DurableService.open`) restores the latest intact snapshot and
replays the WAL tail -- and because every growth/compaction decision of
the service is a deterministic function of (state, chunk, decision
knobs), the recovered run is **bit-identical** to the uninterrupted one
at every committed generation: same labels, same table layout, same
generation trajectory.  The crash-injection suite
(``tests/test_durability.py``) holds this equality under truncation at
arbitrary WAL byte offsets and mid-snapshot crashes.

Protocol per update chunk (all under the service ``_apply_lock``)::

    append(gen_before, chunk) -> fsync batch -> apply -> commit
                                       |          `-- on error: rollback
                                       |              (truncate record)
                                       `-- crash here replays the chunk
                                           on recovery (never acked, so
                                           convergence, not loss)

A fresh service writes a synchronous generation-0 boot snapshot, so
read replicas (:mod:`repro.core.replicas`) can always bootstrap from a
snapshot + tail instead of special-casing an empty store.

High availability (PR 10): pass a held :class:`repro.ha.lease.FileLease`
and the service becomes the *leader* role of the failover story -- its
WAL segments are stamped with the lease epoch (the fencing token), a
heartbeat renews the lease off the apply path, and losing it (takeover,
renewal failure, or an epoch fence hit on append) flips the store into
a permanently self-fenced state where updates raise a typed
:class:`~repro.fault.errors.NotLeader` carrying the current leader as a
hint -- reads keep serving the committed state.  Promotion of a replica
into a new ``DurableService`` lives in
:meth:`repro.core.replicas.Replica.promote`.
"""
from __future__ import annotations

import dataclasses
import os
import threading
import time
from typing import Sequence

import numpy as np

from repro.ckpt import checkpoint, oplog
from repro.core import graph_state as gs
from repro.core.service import SCCService
from repro.fault import errors as fault_errors

__all__ = ["DurableService", "decision_kwargs", "scratch_replay",
           "wal_dir", "snap_dir", "HEALTHY", "DEGRADED", "FENCED"]

HEALTHY = "healthy"
DEGRADED = "degraded"
FENCED = "fenced"


def wal_dir(directory: str) -> str:
    return os.path.join(directory, "wal")


def snap_dir(directory: str) -> str:
    return os.path.join(directory, "snap")


def _cfg_meta(cfg: gs.GraphConfig) -> dict:
    d = dataclasses.asdict(cfg)
    assert d.pop("label_spec") is None, \
        "durable snapshots do not serialize label_spec meshes"
    d["label_spec"] = None
    d["region_edge_buckets"] = list(cfg.region_edge_buckets)
    return d


def decision_kwargs(meta: dict) -> dict:
    """SCCService kwargs recovery/replicas must reuse from a snapshot's
    meta so replay reproduces the writer's growth/compaction decisions
    (and hence its exact generation trajectory and table layout)."""
    svc = meta["service"]
    return {
        "buckets": tuple(svc["buckets"]),
        "grow_factor": svc["grow_factor"],
        "max_edge_capacity": svc["max_edge_capacity"],
        "compact_tomb_frac": svc["compact_tomb_frac"],
        "proactive_grow": svc["proactive_grow"],
    }


def scratch_replay(directory: str, from_step: int = 0,
                   to_gen: int | None = None) -> SCCService:
    """Independent recovery oracle: replay the FULL WAL on top of the
    snapshot at ``from_step`` (default: the generation-0 boot snapshot)
    through a plain in-memory service.  Comparing this against
    :meth:`DurableService.open` (latest snapshot + tail) checks the two
    recovery paths agree bit-for-bit -- the crash-smoke's ground truth
    when the uninterrupted writer is gone (it was SIGKILLed)."""
    st, cfg, meta, _ = checkpoint.restore_graph_snapshot(
        snap_dir(directory), step=from_step)
    if st is None:
        raise FileNotFoundError(f"no snapshot {from_step} in {directory!r}")
    svc = SCCService(cfg, state=st, **decision_kwargs(meta))
    for rec in oplog.read_log(wal_dir(directory), from_gen=svc.gen):
        if to_gen is not None and svc.gen >= to_gen:
            break
        if rec.gen_before < svc.gen:
            continue
        if rec.gen_before != svc.gen:
            raise fault_errors.WalGap(
                f"WAL gap at generation {svc.gen}")
        svc._apply_ops(rec.kind, rec.u, rec.v)
    return svc


class DurableService(SCCService):
    """SCCService whose commits survive the process.

    Construct directly for a *fresh* store (boot snapshot is written
    synchronously at the initial generation); use :meth:`open` to
    recover an existing one (or transparently create it).
    """

    def __init__(self, cfg: gs.GraphConfig, directory: str, *,
                 state: gs.GraphState | None = None,
                 sync_every: int = 1, segment_bytes: int = 4 << 20,
                 snapshot_every: int = 256, snapshot_keep: int = 3,
                 trim_on_snapshot: bool = True,
                 boot_snapshot: bool = True, _defer_wal: bool = False,
                 recover_probe_s: float = 0.05, lease=None,
                 **service_kwargs):
        super().__init__(cfg, state=state, **service_kwargs)
        self._dir = directory
        self._wal_path = wal_dir(directory)
        self._snap_path = snap_dir(directory)
        os.makedirs(self._wal_path, exist_ok=True)
        os.makedirs(self._snap_path, exist_ok=True)
        self._sync_every = sync_every
        self._segment_bytes = segment_bytes
        self._snapshot_every = int(snapshot_every)
        self._snapshot_keep = snapshot_keep
        self._trim_on_snapshot = trim_on_snapshot
        self._snap_thread: threading.Thread | None = None
        self._last_snap_gen = -1
        self.snapshot_count = 0
        self.replayed_wal_records = 0
        self._wal: oplog.OpLogWriter | None = None
        # leadership (see module docstring): the lease's epoch is the
        # WAL fencing token; once fenced/crashed the store never writes
        # again and updates bounce typed NotLeader with a leader hint
        self._lease = lease
        if lease is not None and not lease.valid:
            raise fault_errors.NotLeader(
                f"cannot open durable writer for {directory!r}: the "
                f"lease is not held", leader=self._leader_hint())
        self._epoch = lease.epoch if lease is not None else 0
        self._fenced = False
        self._fenced_error: BaseException | None = None
        self._crashed = False
        self.notleader_rejects = 0
        # degraded-mode state machine (see `health`): a WAL disk fault
        # flips writes off while reads keep serving the committed state;
        # probes rate-limited by recover_probe_s re-attach when it heals
        self._degraded = False
        self._degraded_error: BaseException | None = None
        self._recover_probe_s = float(recover_probe_s)
        self._last_probe = 0.0
        self.degraded_count = 0
        self.recovered_count = 0
        self.unavailable_rejects = 0
        self.snapshot_failures = 0
        if boot_snapshot and \
                checkpoint.latest_step(self._snap_path) is None:
            self.snapshot_now()
        if not _defer_wal:
            self._attach_wal()
        if lease is not None:
            lease.start_heartbeat()

    # ---------------------------------------------------------- opening ---

    @classmethod
    def open(cls, directory: str, cfg: gs.GraphConfig | None = None, *,
             state: gs.GraphState | None = None, to_gen: int | None = None,
             sync_every: int = 1, segment_bytes: int = 4 << 20,
             snapshot_every: int = 256, snapshot_keep: int = 3,
             trim_on_snapshot: bool = True, recover_probe_s: float = 0.05,
             lease=None, **service_kwargs) -> "DurableService":
        """Recover (or create) the durable store at ``directory``.

        Recovery restores the latest intact snapshot, reconstructs the
        service with the snapshot's decision knobs (perf-only kwargs --
        ``inflight_window``, ``scan_lengths``, ``donate`` -- may be
        passed and differ freely: they never change results or the
        generation trajectory), replays the WAL tail, and reopens the
        log for appending.  ``to_gen`` stops the replay at the first
        committed generation ``>= to_gen`` and leaves the service
        *read-only* (no WAL attached) -- the time-travel hook the
        crash-injection tests use to compare against the uninterrupted
        run at an arbitrary generation.
        """
        st, rcfg, meta, _ = checkpoint.restore_graph_snapshot(
            snap_dir(directory))
        durable_kw = dict(sync_every=sync_every,
                          segment_bytes=segment_bytes,
                          snapshot_every=snapshot_every,
                          snapshot_keep=snapshot_keep,
                          trim_on_snapshot=trim_on_snapshot,
                          recover_probe_s=recover_probe_s, lease=lease)
        if st is None:
            if cfg is None:
                raise FileNotFoundError(
                    f"no snapshot under {directory!r} and no GraphConfig "
                    f"given for a fresh store")
            return cls(cfg, directory, state=state, **durable_kw,
                       **service_kwargs)
        kwargs = {**service_kwargs, **decision_kwargs(meta)}
        self = cls(rcfg, directory, state=st, boot_snapshot=False,
                   _defer_wal=True, **durable_kw, **kwargs)
        self._last_snap_gen = int(meta["gen"])
        self._replay(to_gen)
        if to_gen is None:
            self._attach_wal()
        return self

    def _replay(self, to_gen: int | None):
        """Apply the WAL tail on top of the restored snapshot (the
        ``_wal is None`` guard in ``_apply_chunk`` keeps replay from
        re-logging itself)."""
        for rec in oplog.read_log(self._wal_path, from_gen=self.gen):
            if to_gen is not None and self.gen >= to_gen:
                break
            if rec.gen_before < self.gen:
                continue  # already inside the snapshot
            if rec.gen_before != self.gen:
                raise fault_errors.WalGap(
                    f"WAL gap: record expects generation "
                    f"{rec.gen_before}, store is at {self.gen}")
            self._apply_chunk(rec.kind, rec.u, rec.v)
            self.replayed_wal_records += 1

    def _attach_wal(self):
        oplog.repair_tail(self._wal_path)
        # a failed append whose rollback never reached the sick disk can
        # leave a valid-but-unapplied record behind; it must not shadow
        # the next chunk logged at the same generation (an OSError here
        # fails the recovery probe -- the disk has not healed)
        oplog.drop_unapplied_tail(self._wal_path, self.gen)
        # leaderless stores adopt the directory's newest epoch (epoch
        # continuity across plain restarts); a leased writer stamps its
        # fencing token explicitly -- a stale lease raises Fenced here
        self._wal = oplog.OpLogWriter(
            self._wal_path, segment_bytes=self._segment_bytes,
            sync_every=self._sync_every, start_gen=self.gen,
            epoch=self._lease.epoch if self._lease is not None else None)
        self._epoch = self._wal.epoch

    # ----------------------------------------------------------- updates --

    def _leader_hint(self) -> str | None:
        """Current lease owner, when it is someone else (the NotLeader
        redirect hint clients reroute on)."""
        if self._lease is None:
            return None
        info = self._lease.peek()
        if info is None or info.owner == self._lease.owner:
            return None
        return info.owner

    def _not_leader(self, why: str, cause: BaseException | None = None):
        self.notleader_rejects += 1
        raise fault_errors.NotLeader(
            f"durable store {self._dir!r}: {why}; reroute to the "
            f"current leader and resubmit (idempotent)",
            leader=self._leader_hint(),
            retry_after=self._lease.ttl_s if self._lease is not None
            else self._recover_probe_s) from cause

    def _apply_chunk(self, kind, u, v) -> np.ndarray:
        with self._apply_lock:
            if self._crashed:
                self._not_leader("writer crashed (chaos injection)")
            if self._fenced:
                self._not_leader("fenced by a higher writer epoch",
                                 self._fenced_error)
            if self._lease is not None and not self._lease.valid:
                # self-fence on lease loss: even though the WAL fence
                # would stop the append anyway, refusing here keeps the
                # failure typed as leadership, not as a disk fault
                self._fenced = True
                self._fenced_error = self._lease.lost_reason
                self._not_leader("write lease lost",
                                 self._lease.lost_reason)
            if self._degraded and not self._try_recover():
                self.unavailable_rejects += 1
                raise fault_errors.Unavailable(
                    f"durable store {self._dir!r} is DEGRADED "
                    f"({self._degraded_error}); reads keep serving the "
                    f"committed snapshot, retry the update",
                    retry_after=self._recover_probe_s)
            if self._wal is None:  # recovery replay / read-only travel
                return super()._apply_chunk(kind, u, v)
            kind = np.asarray(kind, np.int32)
            u = np.asarray(u, np.int32)
            v = np.asarray(v, np.int32)
            # write-ahead: the record must be durable before any effect
            # of the chunk can commit; a crash after the append replays
            # an unacknowledged chunk, which converges (never diverges)
            try:
                self._wal.append(self.gen, kind, u, v)
            except fault_errors.Fenced as e:
                # a higher epoch owns the log: nothing was written and
                # nothing may ever be again -- permanent self-fence
                self._fenced = True
                self._fenced_error = e
                self._not_leader("fenced by a higher writer epoch", e)
            except OSError as e:
                # nothing applied: reject this chunk as retryable and
                # flip to DEGRADED (reads unaffected)
                self._enter_degraded(e)
                raise fault_errors.Unavailable(
                    f"WAL append failed ({e}); store DEGRADED",
                    retry_after=self._recover_probe_s) from e
            try:
                ok = super()._apply_chunk(kind, u, v)
            except Exception:
                try:
                    self._wal.rollback_last()
                except OSError as e:  # disk died under the rollback too
                    self._enter_degraded(e)
                raise
            # the chunk is committed and durable past this point: house-
            # keeping failures (rotation, snapshot kick) must degrade the
            # store, never un-ack the chunk -- failing here would make a
            # committed chunk look failed and a client retry double-apply
            try:
                self._wal.maybe_rotate(self.gen)
            except fault_errors.Fenced as e:  # fence landed mid-commit:
                self._fenced = True           # this chunk is durable at
                self._fenced_error = e        # our epoch; the NEXT one
            except OSError as e:              # bounces NotLeader
                self._enter_degraded(e)
            self._maybe_snapshot()
            return ok

    def sync(self):
        """Force-fsync any batched WAL appends (the ``sync_every > 1``
        durability window closes here).  A failed sync degrades the
        store and raises :class:`~repro.fault.errors.Unavailable`."""
        if self._wal is not None:
            with self._apply_lock:
                try:
                    self._wal.sync()
                except OSError as e:
                    self._enter_degraded(e)
                    raise fault_errors.Unavailable(
                        f"WAL fsync failed ({e}); store DEGRADED",
                        retry_after=self._recover_probe_s) from e

    # ----------------------------------------------------- degraded mode --

    @property
    def health(self) -> str:
        """``"healthy"`` (read-write), ``"degraded"`` (read-only: the
        WAL disk is refusing writes; queries keep answering from the
        committed state, updates raise ``Unavailable(retry_after)``
        until a probe re-attaches the log), or ``"fenced"`` (read-only
        forever: leadership moved to a higher epoch -- updates raise
        ``NotLeader`` with the new leader as a hint)."""
        if self._fenced or self._crashed:
            return FENCED
        return DEGRADED if self._degraded else HEALTHY

    @property
    def epoch(self) -> int:
        """The writer epoch stamped on this store's WAL segments."""
        return self._epoch

    @property
    def lease(self):
        return self._lease

    def crash(self):
        """Chaos hook: make this writer behave as if SIGKILLed -- the
        lease heartbeat stops (WITHOUT backdating: failover must wait
        out the TTL, the realistic path), no clean WAL close happens,
        and every later update bounces :class:`~repro.fault.errors.
        NotLeader` the way a connection to a dead process would."""
        self._crashed = True
        if self._lease is not None:
            self._lease.abandon()

    def _enter_degraded(self, e: BaseException):
        """Flip to read-only after a WAL-side OSError (idempotent).  The
        current segment's unacknowledged tail bytes are best-effort
        discarded; ``repair_tail`` at recovery covers the rest."""
        if self._degraded:
            return
        self._degraded = True
        self._degraded_error = e
        self.degraded_count += 1
        self._last_probe = time.monotonic()
        if self._wal is not None:
            self._wal.discard_tail()

    def _try_recover(self, force: bool = False) -> bool:
        """Probe the disk (rate-limited) and re-attach the WAL if it
        heals: repair the torn tail, open a fresh segment -- whose
        header write + fsync IS the probe.  Caller holds _apply_lock."""
        if self._fenced:
            return False  # leadership is gone for good, not a disk blip
        now = time.monotonic()
        if not force and now - self._last_probe < self._recover_probe_s:
            return False
        self._last_probe = now
        old, self._wal = self._wal, None
        if old is not None:
            try:
                old.close()
            except OSError:
                pass
        try:
            self._attach_wal()
        except fault_errors.Fenced as e:
            self._fenced = True
            self._fenced_error = e
            return False
        except OSError:
            return False  # still sick; _wal stays None, _degraded True
        self._degraded = False
        self._degraded_error = None
        self.recovered_count += 1
        return True

    def probe_recovery(self) -> bool:
        """Explicitly probe a DEGRADED store (ignores the rate limit);
        returns True when healthy (recovered or never degraded)."""
        with self._apply_lock:
            if not self._degraded:
                return True
            return self._try_recover(force=True)

    # --------------------------------------------------------- snapshots --

    def _snapshot_meta(self, cfg: gs.GraphConfig, gen: int) -> dict:
        return {
            "gen": int(gen),
            "epoch": int(self._epoch),
            "cfg": _cfg_meta(cfg),
            "service": {
                "buckets": list(self._sched.buckets),
                "grow_factor": self._grow_factor,
                "max_edge_capacity": self._max_edge_capacity,
                "compact_tomb_frac": self._compact_tomb_frac,
                "proactive_grow": self._proactive_grow,
            },
        }

    def _write_snapshot(self, state: gs.GraphState, cfg: gs.GraphConfig,
                        gen: int):
        checkpoint.save_graph_snapshot(
            self._snap_path, state, self._snapshot_meta(cfg, gen),
            keep=self._snapshot_keep)
        self.snapshot_count += 1
        if self._trim_on_snapshot:
            oplog.trim(self._wal_path, gen)

    def _write_snapshot_bg(self, state: gs.GraphState,
                           cfg: gs.GraphConfig, gen: int):
        """Background-thread snapshot wrapper: a failed snapshot is a
        durability *cadence* miss, never a serving failure -- the WAL
        still covers every commit.  Count it and let a later commit
        retry (the snapshot floor is rolled back)."""
        try:
            self._write_snapshot(state, cfg, gen)
        except OSError:
            self.snapshot_failures += 1
            if self._last_snap_gen == gen:
                self._last_snap_gen = -1  # let the next commit re-kick

    def _maybe_snapshot(self):
        """Kick an async snapshot of the committed state every
        ``snapshot_every`` generations (0 disables).  The state pytree is
        immutable, so the background thread needs no coordination with
        the update path beyond capturing (state, cfg, gen) coherently --
        which the caller's ``_apply_lock`` provides."""
        if self._snapshot_every <= 0:
            return
        if self.gen - max(self._last_snap_gen, 0) < self._snapshot_every:
            return
        if self._snap_thread is not None and self._snap_thread.is_alive():
            return  # one snapshot in flight at a time; next commit retries
        state, cfg, gen = self._committed, self._cfg, self.gen
        self._last_snap_gen = gen
        self._snap_thread = threading.Thread(
            target=self._write_snapshot_bg, args=(state, cfg, gen),
            name="scc-snapshotter", daemon=True)
        self._snap_thread.start()

    def snapshot_now(self) -> int:
        """Synchronously snapshot the committed state; returns its gen."""
        with self._apply_lock:
            state, cfg, gen = self._committed, self._cfg, self.gen
            self._last_snap_gen = gen
        self._write_snapshot(state, cfg, gen)
        return gen

    def close(self, snapshot: bool = False):
        """Flush + close the WAL (optionally snapshotting first) and wait
        out any in-flight background snapshot."""
        if snapshot:
            self.snapshot_now()
        if self._snap_thread is not None:
            self._snap_thread.join()
            self._snap_thread = None
        if self._wal is not None:
            try:
                self._wal.close()
            except OSError as e:  # final fsync on a sick disk
                self._enter_degraded(e)
            self._wal = None
        if self._lease is not None and not self._crashed:
            self._lease.release()  # graceful handoff: successor takes
            # over on its next poll instead of waiting out a full TTL

    # -------------------------------------------------------------- misc --

    @property
    def directory(self) -> str:
        return self._dir

    def stats(self) -> dict:
        out = super().stats()
        out.update(self._wal.stats() if self._wal is not None
                   else {"wal_appended": 0})
        out.update(snapshots=self.snapshot_count,
                   last_snapshot_gen=self._last_snap_gen,
                   replayed_wal_records=self.replayed_wal_records,
                   health=self.health,
                   epoch=self._epoch,
                   degraded_count=self.degraded_count,
                   recovered_count=self.recovered_count,
                   unavailable_rejects=self.unavailable_rejects,
                   notleader_rejects=self.notleader_rejects,
                   snapshot_failures=self.snapshot_failures)
        if self._lease is not None:
            out.update(self._lease.stats())
        return out
