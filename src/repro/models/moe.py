"""Mixture-of-Experts FFN: top-k router + capacity-bounded dispatch.

Two dispatch strategies, selectable per config (both exact up to token
dropping at the capacity bound):

* ``einsum``  -- GShard-style one-hot dispatch/combine tensors
  [T, E, C].  Shards cleanly under GSPMD (E on the 'model'/expert axis,
  T on 'data'); the dispatch einsums lower to all-to-all-free masked
  matmuls; the paper-standard baseline.
* ``sort``    -- argsort tokens by expert, gather into [E, C, D]
  buffers, scatter back.  O(T·k·D) data movement instead of O(T·E·C·D)
  dispatch FLOPs; the beyond-baseline variant used in §Perf hillclimbs.

Router: softmax-then-top-k (Switch/GShard convention), probs renormalized
over the chosen k, with the standard load-balancing auxiliary loss
(Switch eq. 4) returned for the trainer.
"""
from __future__ import annotations

import dataclasses
from typing import Literal

import jax
import jax.numpy as jnp

from repro.models import common


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_model: int
    d_ff: int                  # per-expert hidden
    n_shared_experts: int = 0  # DeepSeek/Moonlight-style always-on experts
    capacity_factor: float = 1.25
    dispatch: Literal["einsum", "sort"] = "einsum"
    # GShard groups: tokens are dispatched per group of T/n_groups, with
    # per-group capacity -- one group per data shard at scale.  A single
    # global group would make capacity O(T_global) and blow the dispatch
    # einsum up by the shard count (measured in EXPERIMENTS.md §Perf).
    n_groups: int = 1
    # optional GSPMD activation constraints (set by launch/steps.py):
    disp_spec: object = None   # PartitionSpec for [G, Tg, E, C]
    expert_spec: object = None  # PartitionSpec for [E, G, C, D]


def init(key, cfg: MoEConfig, dtype=jnp.float32):
    ks = common.split_keys(key, ["router", "gate", "up", "down", "sh"])
    e, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff
    p = {
        "router": common.dense_init(ks["router"], (d, e), dtype=dtype),
        "w_gate": common.dense_init(ks["gate"], (e, d, f), dtype=dtype),
        "w_up": common.dense_init(ks["up"], (e, d, f), dtype=dtype),
        "w_down": common.dense_init(ks["down"], (e, f, d), dtype=dtype),
    }
    if cfg.n_shared_experts:
        fs = f * cfg.n_shared_experts
        k1, k2, k3 = jax.random.split(ks["sh"], 3)
        p["shared"] = {
            "w_gate": common.dense_init(k1, (d, fs), dtype=dtype),
            "w_up": common.dense_init(k2, (d, fs), dtype=dtype),
            "w_down": common.dense_init(k3, (fs, d), dtype=dtype),
        }
    return p


def _capacity(t: int, cfg: MoEConfig) -> int:
    c = int(t * cfg.top_k / cfg.n_experts * cfg.capacity_factor)
    return max(c, cfg.top_k)


def _router(params, x, cfg: MoEConfig):
    """x: [T, D] -> (probs [T,E], top idx [T,k], top weight [T,k], aux)."""
    logits = (x.astype(jnp.float32) @
              params["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_i = jax.lax.top_k(probs, cfg.top_k)
    top_w = top_w / jnp.maximum(jnp.sum(top_w, -1, keepdims=True), 1e-9)
    # Switch aux loss: E * mean(frac_tokens_e * frac_prob_e)
    t = x.shape[0]
    onehot = jax.nn.one_hot(top_i, cfg.n_experts, dtype=jnp.float32)
    frac_tok = jnp.mean(jnp.sum(onehot, axis=1), axis=0)
    frac_prob = jnp.mean(probs, axis=0)
    aux = cfg.n_experts * jnp.sum(frac_tok * frac_prob)
    return probs, top_i, top_w, aux


def _expert_ffn(params, xe):
    """xe: [E, C, D] -> [E, C, D] (SwiGLU per expert)."""
    g = jnp.einsum("ecd,edf->ecf", xe, params["w_gate"])
    u = jnp.einsum("ecd,edf->ecf", xe, params["w_up"])
    h = jax.nn.silu(g) * u
    return jnp.einsum("ecf,efd->ecd", h, params["w_down"])


def _apply_einsum(params, x, cfg: MoEConfig):
    t, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    g = cfg.n_groups if cfg.n_groups > 0 and t % cfg.n_groups == 0 else 1
    tg = t // g
    c = _capacity(tg, cfg)
    _, top_i, top_w, aux = _router(params, x, cfg)
    # position of each (token, slot) within its expert queue, per group
    onehot = jax.nn.one_hot(top_i, e, dtype=jnp.int32)   # [T,k,E]
    oh_g = onehot.reshape(g, tg, k, e)
    flat = oh_g.reshape(g, tg * k, e)
    pos = jnp.cumsum(flat, axis=1) * flat - 1            # 0-based in expert
    pos = pos.reshape(g, tg, k, e)
    in_cap = (pos >= 0) & (pos < c)
    pos_c = jnp.clip(pos, 0, c - 1)
    disp = (jax.nn.one_hot(pos_c, c, dtype=x.dtype)
            * in_cap[..., None].astype(x.dtype)
            * oh_g[..., None].astype(x.dtype))           # [G,Tg,k,E,C]
    combine = disp * top_w.reshape(g, tg, k, 1, 1).astype(x.dtype)
    disp = jnp.sum(disp, axis=2)                         # [G,Tg,E,C]
    combine = jnp.sum(combine, axis=2)                   # [G,Tg,E,C]
    if cfg.disp_spec is not None:
        disp = jax.lax.with_sharding_constraint(disp, cfg.disp_spec)
        combine = jax.lax.with_sharding_constraint(combine, cfg.disp_spec)
    xg = x.reshape(g, tg, d)
    xe = jnp.einsum("gtec,gtd->egcd", disp, xg)          # [E,G,C,D]
    if cfg.expert_spec is not None:
        # the G<->E transpose is GShard's all-to-all (dp <-> expert axis)
        xe = jax.lax.with_sharding_constraint(xe, cfg.expert_spec)
    ye = _expert_ffn(params, xe.reshape(e, g * c, d)).reshape(e, g, c, d)
    if cfg.expert_spec is not None:
        ye = jax.lax.with_sharding_constraint(ye, cfg.expert_spec)
    y = jnp.einsum("gtec,egcd->gtd", combine, ye)
    return y.reshape(t, d), aux


def _apply_sort(params, x, cfg: MoEConfig):
    t, d = x.shape
    c = _capacity(t, cfg)
    e = cfg.n_experts
    _, top_i, top_w, aux = _router(params, x, cfg)
    flat_e = top_i.reshape(-1)                  # [T*k] expert of each slot
    flat_t = jnp.repeat(jnp.arange(t), cfg.top_k)
    flat_w = top_w.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)    # group slots by expert
    se, st_, sw = flat_e[order], flat_t[order], flat_w[order]
    # position within expert group
    idx = jnp.arange(t * cfg.top_k)
    start = jnp.searchsorted(se, jnp.arange(e), side="left")
    pos = idx - start[se]
    keep = pos < c
    buf_slot = jnp.where(keep, se * c + pos, e * c)  # OOB drop slot
    xe = jnp.zeros((e * c + 1, d), x.dtype).at[buf_slot].add(x[st_])
    ye = _expert_ffn(params, xe[:e * c].reshape(e, c, d)).reshape(e * c, d)
    contrib = ye[jnp.where(keep, se * c + pos, 0)] * \
        (sw * keep.astype(sw.dtype))[:, None].astype(x.dtype)
    y = jnp.zeros_like(x).at[st_].add(contrib)
    return y, aux


def apply(params, x, cfg: MoEConfig):
    """x: [T, D] -> (y [T, D], aux_loss scalar)."""
    if cfg.dispatch == "einsum":
        y, aux = _apply_einsum(params, x, cfg)
    else:
        y, aux = _apply_sort(params, x, cfg)
    if cfg.n_shared_experts:
        sh = params["shared"]
        h = jax.nn.silu(x @ sh["w_gate"]) * (x @ sh["w_up"])
        y = y + h @ sh["w_down"]
    return y, aux
