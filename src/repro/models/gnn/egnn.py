"""EGNN (Satorras et al., arXiv:2102.09844): E(n)-equivariant GNN.

Invariant messages from squared distances; positions updated along relative
vectors -- equivariance by construction, no spherical machinery needed.
Layers are homogeneous and scanned.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.graph import segment_ops as so
from repro.models import common
from repro.models.gnn import common as gc
from repro.models.gnn import tasks


@dataclasses.dataclass(frozen=True)
class EGNNConfig:
    name: str = "egnn"
    n_layers: int = 4
    d_hidden: int = 64
    d_feat: int = 16
    task: str = "energy"       # 'energy' | 'node_class'
    n_classes: int = 2
    n_graphs: int = 1          # graphs per packed batch (static)
    update_pos: bool = True
    dtype: object = jnp.float32
    scan_unroll: bool = False
    edge_ax: object = None
    node_ax: object = None
    remat: bool = False


def _layer_init(key, cfg: EGNNConfig):
    d = cfg.d_hidden
    ks = common.split_keys(key, ["e", "x", "h"])
    return {
        "phi_e": common.mlp_init(ks["e"], [2 * d + 1, d, d], cfg.dtype),
        "phi_x": common.mlp_init(ks["x"], [d, d, 1], cfg.dtype),
        "phi_h": common.mlp_init(ks["h"], [2 * d, d, d], cfg.dtype),
    }


def init(key, cfg: EGNNConfig):
    k_in, k_l, k_out = jax.random.split(key, 3)
    layer_keys = jax.random.split(k_l, cfg.n_layers)
    d_out = cfg.n_classes if cfg.task == "node_class" else 1
    return {
        "embed": common.dense_init(k_in, (cfg.d_feat, cfg.d_hidden),
                                   dtype=cfg.dtype),
        "layers": jax.vmap(lambda k: _layer_init(k, cfg))(layer_keys),
        "head": common.mlp_init(k_out, [cfg.d_hidden, cfg.d_hidden, d_out],
                                cfg.dtype),
    }


def _forward(params, pos, batch, cfg: EGNNConfig):
    src, dst = batch["src"], batch["dst"]
    emask = batch["edge_mask"].astype(cfg.dtype)[:, None]
    n = batch["x"].shape[0]
    h = batch["x"].astype(cfg.dtype) @ params["embed"]

    def body(carry, p):
        h, pos = carry
        rel = pos[dst] - pos[src]                       # [E,3]
        d2 = jnp.sum(rel * rel, -1, keepdims=True)
        m = common.mlp_apply(
            p["phi_e"],
            jnp.concatenate([h[dst], h[src], d2.astype(cfg.dtype)], -1),
            final_act=jax.nn.silu) * emask
        if cfg.update_pos:
            w = common.mlp_apply(p["phi_x"], m)          # [E,1]
            # +eps inside the sqrt keeps grads finite on zero-length
            # (padded / self-loop) edges
            delta = rel / (jnp.sqrt(d2 + 1e-9) + 1.0) * w * emask
            pos = pos + so.segment_mean(delta, dst, n)
        m = gc.constrain_rows(m, cfg.edge_ax)
        agg = so.segment_sum(m, dst, n)
        h = h + common.mlp_apply(
            p["phi_h"], jnp.concatenate([h, agg], -1))
        h = gc.constrain_rows(h, cfg.node_ax)
        return (h, pos), None

    if cfg.remat:
        body = jax.checkpoint(body)
    (h, pos), _ = jax.lax.scan(body, (h, pos), params["layers"],
                               unroll=bool(cfg.scan_unroll))
    return h, pos


def node_energy(params, pos, batch, cfg: EGNNConfig):
    h, _ = _forward(params, pos, batch, cfg)
    e_node = common.mlp_apply(params["head"], h)[:, 0]
    return tasks.per_graph_sum(e_node, batch["graph_id"],
                               batch["node_mask"], cfg.n_graphs)


def loss_fn(params, batch, cfg: EGNNConfig):
    if cfg.task == "node_class":
        h, _ = _forward(params, batch["pos"], batch, cfg)
        logits = common.mlp_apply(params["head"], h)
        return tasks.classification_loss(logits, batch)
    return tasks.energy_force_loss(
        lambda p, pos, b: node_energy(p, pos, b, cfg),
        params, batch, cfg.n_graphs)
