"""Equivariant GNN substrate: Cartesian irreps (l <= 2), tensor products,
radial bases, gates.

Instead of spherical-basis CG coefficients we carry irreps in Cartesian
form -- l=0 scalars, l=1 vectors (3,), l=2 symmetric-traceless matrices
(3,3) -- where every allowed product l1 ⊗ l2 -> l3 is an explicit tensor
contraction (dot, cross, traceless-symmetric outer, epsilon contraction).
For l <= 2 this spans the same equivariant bilinear maps as the spherical
construction (per-path constants are absorbed by learned path weights), is
exactly SO(3)-equivariant, and lowers to plain einsums -- MXU work, no
gather-heavy irrep bookkeeping.  Feature pytrees:

    {"l0": [N, C], "l1": [N, C, 3], "l2": [N, C, 3, 3]}

All tensor-product helpers broadcast over leading dims, so they serve both
edge-message products (feature × edge basis, basis as channel-dim 1) and
MACE's node-wise A×A products (channel-aligned).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

EPS3 = jnp.array([[[0, 0, 0], [0, 0, 1], [0, -1, 0]],
                  [[0, 0, -1], [0, 0, 0], [1, 0, 0]],
                  [[0, 1, 0], [-1, 0, 0], [0, 0, 0]]], jnp.float32)


def sym_traceless(m):
    """Project [..., 3, 3] onto the l=2 (symmetric traceless) component."""
    s = 0.5 * (m + jnp.swapaxes(m, -1, -2))
    tr = jnp.trace(s, axis1=-2, axis2=-1)[..., None, None]
    eye = jnp.eye(3, dtype=m.dtype)
    return s - tr * eye / 3.0


# --- tensor products: a has irrep l1, b has irrep l2, result lout ----------

def _tp00_0(a, b):
    return a * b


def _tp01_1(a, b):
    return a[..., None] * b


def _tp02_2(a, b):
    return a[..., None, None] * b


def _tp10_1(a, b):
    return a * b[..., None]


def _tp11_0(a, b):
    return jnp.einsum("...i,...i->...", a, b)


def _tp11_1(a, b):
    return jnp.cross(a, b)


def _tp11_2(a, b):
    return sym_traceless(a[..., :, None] * b[..., None, :])


def _tp12_1(a, b):
    return jnp.einsum("...i,...ij->...j", a, b)


def _tp12_2(a, b):
    return sym_traceless(jnp.einsum("iab,...a,...bj->...ij",
                                    EPS3.astype(a.dtype), a, b))


def _tp20_2(a, b):
    return a * b[..., None, None]


def _tp21_1(a, b):
    return jnp.einsum("...ij,...j->...i", a, b)


def _tp21_2(a, b):
    return _tp12_2(b, a)


def _tp22_0(a, b):
    return jnp.einsum("...ij,...ij->...", a, b)


def _tp22_1(a, b):
    return jnp.einsum("iab,...ak,...kb->...i", EPS3.astype(a.dtype), a, b)


def _tp22_2(a, b):
    return sym_traceless(jnp.einsum("...ik,...kj->...ij", a, b))


# (l_a, l_b, l_out) -> bilinear map; the full l<=2 path table.
TP_PATHS = {
    (0, 0, 0): _tp00_0,
    (0, 1, 1): _tp01_1,
    (0, 2, 2): _tp02_2,
    (1, 0, 1): _tp10_1,
    (1, 1, 0): _tp11_0,
    (1, 1, 1): _tp11_1,
    (1, 1, 2): _tp11_2,
    (1, 2, 1): _tp12_1,
    (1, 2, 2): _tp12_2,
    (2, 0, 2): _tp20_2,
    (2, 1, 1): _tp21_1,
    (2, 1, 2): _tp21_2,
    (2, 2, 0): _tp22_0,
    (2, 2, 1): _tp22_1,
    (2, 2, 2): _tp22_2,
}


def paths_for(l_max: int):
    return [(la, lb, lo) for (la, lb, lo) in TP_PATHS
            if la <= l_max and lb <= l_max and lo <= l_max]


def zeros_feats(n: int, c: int, l_max: int, dtype=jnp.float32):
    f = {"l0": jnp.zeros((n, c), dtype)}
    if l_max >= 1:
        f["l1"] = jnp.zeros((n, c, 3), dtype)
    if l_max >= 2:
        f["l2"] = jnp.zeros((n, c, 3, 3), dtype)
    return f


def edge_basis(rhat, l_max: int):
    """Cartesian Y_l of unit edge vectors with a channel-1 dim for
    broadcasting against [E, C, ...] features.  rhat: [E, 3]."""
    out = {"l0": jnp.ones((rhat.shape[0], 1), rhat.dtype)}
    if l_max >= 1:
        out["l1"] = rhat[:, None, :]
    if l_max >= 2:
        out["l2"] = sym_traceless(
            rhat[:, :, None] * rhat[:, None, :])[:, None, :, :]
    return out


def bessel_basis(r, n_rbf: int, cutoff: float):
    """Radial Bessel basis with smooth polynomial cutoff.  r: [E]."""
    r = jnp.maximum(r, 1e-9)
    n = jnp.arange(1, n_rbf + 1, dtype=r.dtype)
    basis = jnp.sqrt(2.0 / cutoff) * jnp.sin(
        n[None, :] * jnp.pi * r[:, None] / cutoff) / r[:, None]
    x = jnp.clip(r / cutoff, 0.0, 1.0)
    env = 1.0 - 10.0 * x ** 3 + 15.0 * x ** 4 - 6.0 * x ** 5  # C² cutoff
    return basis * env[:, None]


def linear_mix(w, feats):
    """Per-l channel mixing.  w: {'l0': [Cin,Cout], ...}."""
    out = {}
    for l, f in feats.items():
        out[l] = jnp.einsum("nc...,cd->nd...", f, w[l])
    return out


def gate(feats, w_gate):
    """Equivariant gate: scalars through silu; l>0 scaled by
    sigmoid(linear(scalars))."""
    s = feats["l0"]
    out = {"l0": jax.nn.silu(s)}
    for l in ("l1", "l2"):
        if l in feats:
            g = jax.nn.sigmoid(s @ w_gate[l])  # [N, C]
            extra = feats[l].ndim - g.ndim
            out[l] = feats[l] * g.reshape(g.shape + (1,) * extra)
    return out


def add_feats(a, b):
    return {l: a[l] + b[l] for l in a}


def norm_feats(feats, eps: float = 1e-6):
    """Invariant RMS normalization per l (divide by channel-mean norm)."""
    out = {}
    for l, f in feats.items():
        sq = f * f
        axes = tuple(range(1, f.ndim))
        ms = jnp.mean(sq, axis=axes, keepdims=True)
        out[l] = f * jax.lax.rsqrt(ms + eps)
    return out


def invariants(feats):
    """Concatenate rotation-invariant contractions of all l channels."""
    parts = [feats["l0"]]
    if "l1" in feats:
        parts.append(jnp.sqrt(jnp.sum(feats["l1"] ** 2, -1) + 1e-12))
    if "l2" in feats:
        parts.append(jnp.sqrt(jnp.einsum("ncij,ncij->nc",
                                         feats["l2"], feats["l2"]) + 1e-12))
    return jnp.concatenate(parts, axis=-1)


def random_rotation(key):
    """Haar-ish random rotation matrix via QR."""
    m = jax.random.normal(key, (3, 3))
    q, r = jnp.linalg.qr(m)
    q = q * jnp.sign(jnp.diag(r))[None, :]
    det = jnp.linalg.det(q)
    return q * jnp.sign(det)  # ensure proper rotation


def rotate_feats(feats, rot):
    out = {"l0": feats["l0"]}
    if "l1" in feats:
        out["l1"] = jnp.einsum("ij,ncj->nci", rot, feats["l1"])
    if "l2" in feats:
        out["l2"] = jnp.einsum("ia,jb,ncab->ncij", rot, rot, feats["l2"])
    return out


def constrain_rows(x, axis):
    """Pin the leading-dim sharding of an intermediate (edge/node arrays).

    ``axis``: mesh axis name (or tuple) for dim 0, or None (no-op).  Used
    to stop GSPMD from replicating the big per-edge message tensors on
    full-batch graphs (measured: mace/ogb went from 447 GiB/device temps
    to sharded residency -- EXPERIMENTS.md §Perf).
    """
    if axis is None:
        return x
    spec = jax.sharding.PartitionSpec(axis, *([None] * (x.ndim - 1)))
    return jax.lax.with_sharding_constraint(x, spec)


def constrain_feats(feats, axis):
    if axis is None:
        return feats
    return {l: constrain_rows(f, axis) for l, f in feats.items()}
