"""MACE (Batatia et al., arXiv:2206.07697): higher-order equivariant
message passing via ACE-style symmetric tensor contractions.

Per layer:
  1. **A-features**: one radial-weighted tensor-product convolution over
     neighbors (same machinery as NequIP) -- the order-1 atomic basis.
  2. **B-features**: symmetric products of A with itself up to
     ``correlation`` order (here 3):  B² = Σ paths TP(A, A),
     B³ = Σ paths TP(B², A), each path carrying a learned per-channel
     weight -- the Cartesian analogue of MACE's contracted products.
  3. Message = Σ_order linear_mix(B^order); update = gate(message + skip).
  4. Per-layer invariant energy readout, summed over layers (MACE's
     multi-readout).

Because every B is built node-locally from A, one MACE layer carries
many-body information at the cost of a single neighbor aggregation --
the paper's key trade, preserved exactly in this formulation.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import common
from repro.models.gnn import common as gc
from repro.models.gnn import nequip as nq
from repro.models.gnn import tasks


@dataclasses.dataclass(frozen=True)
class MACEConfig:
    name: str = "mace"
    n_layers: int = 2
    d_hidden: int = 128
    l_max: int = 2
    correlation: int = 3
    n_rbf: int = 8
    cutoff: float = 5.0
    d_feat: int = 16
    task: str = "energy"
    n_classes: int = 2
    n_graphs: int = 1
    avg_degree: float = 8.0
    dtype: object = jnp.float32
    scan_unroll: bool = False
    edge_ax: object = None
    node_ax: object = None
    remat: bool = False
    edge_chunk: int = 0


def _ls(cfg):
    return ["l0", "l1", "l2"][: cfg.l_max + 1]


def _node_paths(l_max: int):
    """(l_a, l_b, l_out) products usable node-locally (both channelled)."""
    return gc.paths_for(l_max)


def _layer_init(key, cfg: MACEConfig):
    c = cfg.d_hidden
    paths = gc.paths_for(cfg.l_max)
    npaths = len(paths)
    ks = common.split_keys(
        key, ["radial", "w2", "w3", "mix1", "mix2", "mix3", "skip",
              "gate", "readout"])
    def mixes(base):
        return {l: common.dense_init(jax.random.fold_in(ks[base], i),
                                     (c, c), dtype=cfg.dtype)
                for i, l in enumerate(_ls(cfg))}
    return {
        "radial": common.mlp_init(
            ks["radial"], [cfg.n_rbf, 32, npaths * c], cfg.dtype),
        # per-path, per-channel weights of the symmetric contractions
        "w2": common.dense_init(ks["w2"], (npaths, c), scale=0.3,
                                dtype=cfg.dtype),
        "w3": common.dense_init(ks["w3"], (npaths, c), scale=0.3,
                                dtype=cfg.dtype),
        "mix1": mixes("mix1"),
        "mix2": mixes("mix2"),
        "mix3": mixes("mix3"),
        "skip": mixes("skip"),
        "gate": {l: common.dense_init(jax.random.fold_in(ks["gate"], i),
                                      (c, c), dtype=cfg.dtype)
                 for i, l in enumerate(_ls(cfg)) if l != "l0"},
        "readout": common.mlp_init(
            ks["readout"], [c * (cfg.l_max + 1), c, 1], cfg.dtype),
    }


def init(key, cfg: MACEConfig):
    k_in, k_l, k_out = jax.random.split(key, 3)
    layer_keys = jax.random.split(k_l, cfg.n_layers)
    d_out = cfg.n_classes if cfg.task == "node_class" else 1
    return {
        "embed": common.dense_init(k_in, (cfg.d_feat, cfg.d_hidden),
                                   dtype=cfg.dtype),
        "layers": jax.vmap(lambda k: _layer_init(k, cfg))(layer_keys),
        "head": common.mlp_init(
            k_out, [cfg.d_hidden * (cfg.l_max + 1), cfg.d_hidden, d_out],
            cfg.dtype),
    }


def _sym_product(a_feats, b_feats, weights, cfg: MACEConfig):
    """Σ_paths w_path ⊙ TP(a, b), node-local (both args [N, C, ...])."""
    paths = gc.paths_for(cfg.l_max)
    out = {l: jnp.zeros_like(a_feats[l]) for l in _ls(cfg)}
    for i, (la, lb, lo) in enumerate(paths):
        prod = gc.TP_PATHS[(la, lb, lo)](a_feats[f"l{la}"],
                                         b_feats[f"l{lb}"])
        w = weights[i]  # [C]
        out[f"l{lo}"] = out[f"l{lo}"] + prod * w.reshape(
            (1, -1) + (1,) * (prod.ndim - 2))
    return out


def _forward(params, pos, batch, cfg: MACEConfig):
    """Returns (final feats, per-node energy accumulated over layers)."""
    n = batch["x"].shape[0]
    feats = gc.zeros_feats(n, cfg.d_hidden, cfg.l_max, cfg.dtype)
    feats["l0"] = batch["x"].astype(cfg.dtype) @ params["embed"]
    # reuse the NequIP conv (A-features) with a cfg view
    nq_cfg = nq.NequIPConfig(
        n_layers=cfg.n_layers, d_hidden=cfg.d_hidden, l_max=cfg.l_max,
        n_rbf=cfg.n_rbf, cutoff=cfg.cutoff, d_feat=cfg.d_feat,
        avg_degree=cfg.avg_degree, dtype=cfg.dtype,
        edge_ax=cfg.edge_ax, node_ax=cfg.node_ax,
        edge_chunk=cfg.edge_chunk)

    def body(carry, p):
        feats, e_acc = carry
        a = nq.conv({"radial": p["radial"]}, feats, pos, batch, nq_cfg)
        a = gc.norm_feats(a)
        b2 = _sym_product(a, a, p["w2"], cfg) if cfg.correlation >= 2 \
            else None
        b3 = _sym_product(b2, a, p["w3"], cfg) if cfg.correlation >= 3 \
            else None
        m = gc.linear_mix(p["mix1"], a)
        if b2 is not None:
            m = gc.add_feats(m, gc.linear_mix(p["mix2"], b2))
        if b3 is not None:
            m = gc.add_feats(m, gc.linear_mix(p["mix3"], b3))
        skip = gc.linear_mix(p["skip"], feats)
        feats = gc.norm_feats(gc.gate(gc.add_feats(m, skip), p["gate"]))
        feats = gc.constrain_feats(feats, cfg.node_ax)
        e_layer = common.mlp_apply(p["readout"], gc.invariants(feats))[:, 0]
        return (feats, e_acc + e_layer), None

    e0 = jnp.zeros((n,), cfg.dtype)
    if cfg.remat:
        body = jax.checkpoint(body)
    (feats, e_acc), _ = jax.lax.scan(body, (feats, e0), params["layers"],
                                     unroll=bool(cfg.scan_unroll))
    return feats, e_acc


def node_energy(params, pos, batch, cfg: MACEConfig):
    _, e_node = _forward(params, pos, batch, cfg)
    return tasks.per_graph_sum(e_node, batch["graph_id"],
                               batch["node_mask"], cfg.n_graphs)


def loss_fn(params, batch, cfg: MACEConfig):
    if cfg.task == "node_class":
        feats, _ = _forward(params, batch["pos"], batch, cfg)
        logits = common.mlp_apply(params["head"], gc.invariants(feats))
        return tasks.classification_loss(logits, batch)
    return tasks.energy_force_loss(
        lambda p, pos, b: node_energy(p, pos, b, cfg),
        params, batch, cfg.n_graphs)
