"""GatedGCN (Bresson & Laurent, arXiv:1711.07553 / benchmark config
arXiv:2003.00982): edge-gated message passing, 16 scanned layers, d=70.

h_i' = h_i + ReLU(Norm(A h_i + Σ_j η_ij ⊙ B h_j)),
e_ij' = e_ij + ReLU(Norm(ê_ij)),  ê_ij = C e_ij + D h_i + E h_j,
η_ij = σ(ê_ij) / (Σ_j' σ(ê_ij') + ε)   (degree-normalized edge gates).

The benchmark uses BatchNorm; we use masked LayerNorm (functional purity;
noted in DESIGN.md §6).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.graph import segment_ops as so
from repro.models import common
from repro.models.gnn import common as gc
from repro.models.gnn import tasks


@dataclasses.dataclass(frozen=True)
class GatedGCNConfig:
    name: str = "gatedgcn"
    n_layers: int = 16
    d_hidden: int = 70
    d_feat: int = 16
    task: str = "node_class"
    n_classes: int = 7
    n_graphs: int = 1
    dtype: object = jnp.float32
    scan_unroll: bool = False
    edge_ax: object = None
    node_ax: object = None
    remat: bool = False


def _layer_init(key, cfg: GatedGCNConfig):
    d = cfg.d_hidden
    ks = common.split_keys(key, list("ABCDE"))
    p = {m: common.dense_init(ks[m], (d, d), dtype=cfg.dtype)
         for m in "ABCDE"}
    p["ln_h"] = jnp.ones((d,), cfg.dtype)
    p["ln_e"] = jnp.ones((d,), cfg.dtype)
    return p


def init(key, cfg: GatedGCNConfig):
    k_in, k_e, k_l, k_out = jax.random.split(key, 4)
    layer_keys = jax.random.split(k_l, cfg.n_layers)
    d_out = cfg.n_classes if cfg.task == "node_class" else 1
    return {
        "embed_h": common.dense_init(k_in, (cfg.d_feat, cfg.d_hidden),
                                     dtype=cfg.dtype),
        "embed_e": common.dense_init(k_e, (1, cfg.d_hidden),
                                     dtype=cfg.dtype),
        "layers": jax.vmap(lambda k: _layer_init(k, cfg))(layer_keys),
        "head": common.mlp_init(k_out, [cfg.d_hidden, cfg.d_hidden, d_out],
                                cfg.dtype),
    }


def _ln(x, w, eps=1e-5):
    mu = jnp.mean(x, -1, keepdims=True)
    var = jnp.var(x, -1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * w


def _forward(params, batch, cfg: GatedGCNConfig):
    src, dst = batch["src"], batch["dst"]
    emask = batch["edge_mask"].astype(cfg.dtype)[:, None]
    n = batch["x"].shape[0]
    h = batch["x"].astype(cfg.dtype) @ params["embed_h"]
    e = jnp.ones((src.shape[0], 1), cfg.dtype) @ params["embed_e"]

    def body(carry, p):
        h, e = carry
        e_hat = e @ p["C"] + h[dst] @ p["D"] + h[src] @ p["E"]
        sig = jax.nn.sigmoid(e_hat) * emask
        denom = so.segment_sum(sig, dst, n)[dst] + 1e-6
        eta = sig / denom
        agg = so.segment_sum(eta * (h[src] @ p["B"]) * emask, dst, n)
        h = h + jax.nn.relu(_ln(h @ p["A"] + agg, p["ln_h"]))
        e = e + jax.nn.relu(_ln(e_hat, p["ln_e"]))
        h = gc.constrain_rows(h, cfg.node_ax)
        e = gc.constrain_rows(e, cfg.edge_ax)
        return (h, e), None

    if cfg.remat:
        body = jax.checkpoint(body)
    (h, e), _ = jax.lax.scan(body, (h, e), params["layers"],
                             unroll=bool(cfg.scan_unroll))
    return h


def node_energy(params, pos, batch, cfg: GatedGCNConfig):
    del pos  # GatedGCN is not geometric; energy from features only
    h = _forward(params, batch, cfg)
    e_node = common.mlp_apply(params["head"], h)[:, 0]
    return tasks.per_graph_sum(e_node, batch["graph_id"],
                               batch["node_mask"], cfg.n_graphs)


def loss_fn(params, batch, cfg: GatedGCNConfig):
    if cfg.task == "node_class":
        logits = common.mlp_apply(params["head"],
                                  _forward(params, batch, cfg))
        return tasks.classification_loss(logits, batch)
    # graph-level energy regression (molecule shape); no force term since
    # the model has no positional pathway -- MSE on energies only.
    e = node_energy(params, batch["pos"], batch, cfg)
    loss = jnp.mean((e - batch["energy"]) ** 2)
    return loss, {"e_mse": loss}
