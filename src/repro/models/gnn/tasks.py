"""Shared GNN task heads/losses.

Every assigned GNN arch must run on all four graph shapes, so each model
supports two task heads:

  * ``node_class`` -- CE over per-node logits (full_graph_sm /
    minibatch_lg / ogb_products);
  * ``energy``     -- per-graph energy = Σ per-node scalar readout, with
    forces = -∂E/∂pos and a combined MSE (molecule shape).

Batch dict convention (all dense, masked):
  src, dst: int32[E]; edge_mask: bool[E]; node_mask: bool[N];
  x: f32[N, d_feat]; pos: f32[N, 3]; graph_id: int32[N];
  labels: int32[N] (classification) or energy: f32[G], forces: f32[N, 3].
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def classification_loss(logits, batch):
    labels = batch["labels"]
    mask = batch["node_mask"]
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
    loss = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1)
    acc = jnp.sum((jnp.argmax(logits, -1) == labels) * mask) / \
        jnp.maximum(jnp.sum(mask), 1)
    return loss, {"ce": loss, "acc": acc}


def energy_force_loss(energy_fn, params, batch, n_graphs: int,
                      force_weight: float = 1.0):
    """energy_fn(params, pos, batch) -> per-graph energies [G]."""

    def total_e(pos):
        return jnp.sum(energy_fn(params, pos, batch))

    e = energy_fn(params, batch["pos"], batch)
    forces = -jax.grad(total_e)(batch["pos"])
    e_err = jnp.mean((e - batch["energy"]) ** 2)
    mask = batch["node_mask"][:, None]
    f_err = jnp.sum(((forces - batch["forces"]) * mask) ** 2) / \
        jnp.maximum(jnp.sum(mask) * 3, 1)
    loss = e_err + force_weight * f_err
    return loss, {"e_mse": e_err, "f_mse": f_err}


def per_graph_sum(node_scalar, graph_id, node_mask, n_graphs: int):
    vals = node_scalar * node_mask
    return jax.ops.segment_sum(vals, graph_id, num_segments=n_graphs)
