"""NequIP (Batzner et al., arXiv:2101.03164): E(3)-equivariant interatomic
potential -- tensor-product convolutions over l<=2 Cartesian irreps.

Per layer: messages are radial-weighted tensor products of neighbor
features with the edge basis Y_l(r̂), summed over all (l_in, l_edge, l_out)
paths, aggregated by scatter-sum, then self-mixed + gated.  Radial weights
come from an MLP on the Bessel basis -- one weight per (path, channel) per
edge, exactly the NequIP parameterization (constants folded into weights).

Energy readout from invariant contractions; forces via -grad (autodiff
through the whole message-passing stack).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.graph import segment_ops as so
from repro.models import common
from repro.models.gnn import common as gc
from repro.models.gnn import tasks


@dataclasses.dataclass(frozen=True)
class NequIPConfig:
    name: str = "nequip"
    n_layers: int = 5
    d_hidden: int = 32
    l_max: int = 2
    n_rbf: int = 8
    cutoff: float = 5.0
    d_feat: int = 16
    task: str = "energy"
    n_classes: int = 2
    n_graphs: int = 1
    avg_degree: float = 8.0
    dtype: object = jnp.float32
    scan_unroll: bool = False
    edge_ax: object = None   # mesh axis for per-edge intermediates
    node_ax: object = None   # mesh axis for per-node intermediates
    remat: bool = False      # checkpoint the layer scan body
    edge_chunk: int = 0      # >0: stream edges through scan chunks of
                             # this size (l=2 message tensors on 10^7+
                             # edge graphs cannot materialize whole)


def _ls(cfg):
    return ["l0", "l1", "l2"][: cfg.l_max + 1]


def _layer_init(key, cfg: NequIPConfig):
    c = cfg.d_hidden
    paths = gc.paths_for(cfg.l_max)
    ks = common.split_keys(key, ["radial", "mix", "gate", "skip"])
    p = {
        # radial MLP emits one weight per (path, channel)
        "radial": common.mlp_init(
            ks["radial"], [cfg.n_rbf, 32, len(paths) * c], cfg.dtype),
        "mix": {l: common.dense_init(jax.random.fold_in(ks["mix"], i),
                                     (c, c), dtype=cfg.dtype)
                for i, l in enumerate(_ls(cfg))},
        "skip": {l: common.dense_init(jax.random.fold_in(ks["skip"], i),
                                      (c, c), dtype=cfg.dtype)
                 for i, l in enumerate(_ls(cfg))},
        "gate": {l: common.dense_init(jax.random.fold_in(ks["gate"], i),
                                      (c, c), dtype=cfg.dtype)
                 for i, l in enumerate(_ls(cfg)) if l != "l0"},
    }
    return p


def init(key, cfg: NequIPConfig):
    k_in, k_l, k_out = jax.random.split(key, 3)
    layer_keys = jax.random.split(k_l, cfg.n_layers)
    d_out = cfg.n_classes if cfg.task == "node_class" else 1
    n_inv = cfg.d_hidden * (cfg.l_max + 1)
    return {
        "embed": common.dense_init(k_in, (cfg.d_feat, cfg.d_hidden),
                                   dtype=cfg.dtype),
        "layers": jax.vmap(lambda k: _layer_init(k, cfg))(layer_keys),
        "head": common.mlp_init(k_out, [n_inv, cfg.d_hidden, d_out],
                                cfg.dtype),
    }


def _chunk_messages(p, feats, pos, s_idx, d_idx, m_mask, n,
                    cfg: NequIPConfig):
    """Messages for one edge set, aggregated to nodes ([N, C, ...])."""
    c = cfg.d_hidden
    rel = pos[d_idx] - pos[s_idx]
    r = jnp.sqrt(jnp.sum(rel * rel, -1) + 1e-12)
    rhat = rel / r[:, None]
    basis = gc.edge_basis(rhat.astype(cfg.dtype), cfg.l_max)
    rbf = gc.bessel_basis(r, cfg.n_rbf, cfg.cutoff).astype(cfg.dtype)
    paths = gc.paths_for(cfg.l_max)
    w = common.mlp_apply(p["radial"], rbf)  # [E, n_paths*C]
    # zero-length (self-loop / padded) edges carry no message: rhat is
    # singular there and its gradient is chaotic -- masking keeps grads
    # exact and chunk-order independent
    ok = m_mask & (r > 1e-6)
    w = w * ok.astype(cfg.dtype)[:, None]
    w = w.reshape(w.shape[0], len(paths), c)
    msg = {l: None for l in _ls(cfg)}
    gathered = {l: gc.constrain_rows(feats[l][s_idx], cfg.edge_ax)
                for l in _ls(cfg)}                   # [E, C, ...] per l
    for i, (la, lb, lo) in enumerate(paths):
        fa = gathered[f"l{la}"]
        yb = basis[f"l{lb}"]                         # [E, 1, ...]
        yb = jnp.broadcast_to(yb, (fa.shape[0], c) + yb.shape[2:])
        out = gc.TP_PATHS[(la, lb, lo)](fa, yb)      # [E, C, ...]
        wi = w[:, i].reshape(w.shape[0], c)
        out = out * wi.reshape(wi.shape + (1,) * (out.ndim - 2))
        out = gc.constrain_rows(out, cfg.edge_ax)
        key = f"l{lo}"
        msg[key] = out if msg[key] is None else msg[key] + out
    msg = {l: gc.constrain_rows(m, cfg.edge_ax) for l, m in msg.items()}
    agg = {l: so.segment_sum(m, d_idx, n) for l, m in msg.items()}
    return gc.constrain_feats(agg, cfg.node_ax)


def conv(p, feats, pos, batch, cfg: NequIPConfig):
    """One tensor-product convolution; returns aggregated messages.

    With ``edge_chunk`` set, edges stream through a scan in fixed-size
    chunks and only chunk-sized message tensors ever exist -- the l=2
    channels of a 6x10^7-edge graph would otherwise need hundreds of GiB
    (measured; EXPERIMENTS.md §Perf).  FLOP metering uses an unchunked
    twin (launch/dryrun.py) because XLA counts scan bodies once.
    """
    src, dst = batch["src"], batch["dst"]
    emask = batch["edge_mask"]
    n = feats["l0"].shape[0]
    e = src.shape[0]
    ck = cfg.edge_chunk
    if ck and e > ck and e % ck == 0:
        nc = e // ck
        sc = src.reshape(nc, ck)
        dc = dst.reshape(nc, ck)
        mc = emask.reshape(nc, ck)

        # custom VJP: agg = Σ_chunks f(chunk); d(agg)/d(inputs) re-streams
        # the chunks in backward instead of letting scan save 32 copies of
        # per-chunk message tensors / node carries (measured: 470 GiB ->
        # chunk-resident).  Valid because the cotangent of a sum is the
        # same for every chunk contribution.  FIRST-ORDER only: force
        # training (grad-of-grad) must run unchunked -- the big-graph
        # shapes that need chunking are all classification cells.
        @jax.custom_vjp
        def _agg(p_, feats_, pos_, sc_, dc_, mc_):
            def body(acc, xs):
                s, d, m = xs
                contrib = _chunk_messages(p_, feats_, pos_, s, d, m, n,
                                          cfg)
                return gc.constrain_feats(gc.add_feats(acc, contrib),
                                          cfg.node_ax), None

            acc0 = gc.constrain_feats(
                gc.zeros_feats(n, cfg.d_hidden, cfg.l_max, cfg.dtype),
                cfg.node_ax)
            out, _ = jax.lax.scan(body, acc0, (sc_, dc_, mc_))
            return out

        def _agg_fwd(p_, feats_, pos_, sc_, dc_, mc_):
            return (_agg(p_, feats_, pos_, sc_, dc_, mc_),
                    (p_, feats_, pos_, sc_, dc_, mc_))

        def _agg_bwd(res, g):
            p_, feats_, pos_, sc_, dc_, mc_ = res

            def body(grads, xs):
                s, d, m = xs
                _, vjp = jax.vjp(
                    lambda a, b, c: _chunk_messages(a, b, c, s, d, m, n,
                                                    cfg),
                    p_, feats_, pos_)
                gp, gf, gx = vjp(g)
                return jax.tree.map(jnp.add, grads, (gp, gf, gx)), None

            zeros = jax.tree.map(jnp.zeros_like, (p_, feats_, pos_))
            (gp, gf, gx), _ = jax.lax.scan(body, zeros, (sc_, dc_, mc_))
            f0 = lambda x: np.zeros(x.shape, jax.dtypes.float0)
            return gp, gf, gx, f0(sc_), f0(dc_), f0(mc_)

        _agg.defvjp(_agg_fwd, _agg_bwd)
        agg = _agg(p, feats, pos, sc, dc, mc)
    else:
        agg = _chunk_messages(p, feats, pos, src, dst, emask, n, cfg)
    scale = jnp.asarray(cfg.avg_degree ** 0.5, cfg.dtype)
    return gc.constrain_feats({l: v / scale for l, v in agg.items()},
                              cfg.node_ax)


def _forward(params, pos, batch, cfg: NequIPConfig):
    n = batch["x"].shape[0]
    feats = gc.zeros_feats(n, cfg.d_hidden, cfg.l_max, cfg.dtype)
    feats["l0"] = batch["x"].astype(cfg.dtype) @ params["embed"]

    def body(feats, p):
        m = conv(p, feats, pos, batch, cfg)
        m = gc.linear_mix(p["mix"], m)
        skip = gc.linear_mix(p["skip"], feats)
        feats = gc.gate(gc.add_feats(m, skip), p["gate"])
        feats = gc.norm_feats(feats)
        return gc.constrain_feats(feats, cfg.node_ax), None

    if cfg.remat:
        body = jax.checkpoint(body)
    feats, _ = jax.lax.scan(body, feats, params["layers"],
                            unroll=bool(cfg.scan_unroll))
    return feats


def node_energy(params, pos, batch, cfg: NequIPConfig):
    feats = _forward(params, pos, batch, cfg)
    inv = gc.invariants(feats)
    e_node = common.mlp_apply(params["head"], inv)[:, 0]
    return tasks.per_graph_sum(e_node, batch["graph_id"],
                               batch["node_mask"], cfg.n_graphs)


def loss_fn(params, batch, cfg: NequIPConfig):
    if cfg.task == "node_class":
        feats = _forward(params, batch["pos"], batch, cfg)
        logits = common.mlp_apply(params["head"], gc.invariants(feats))
        return tasks.classification_loss(logits, batch)
    return tasks.energy_force_loss(
        lambda p, pos, b: node_energy(p, pos, b, cfg),
        params, batch, cfg.n_graphs)
