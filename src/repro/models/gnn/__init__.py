from repro.models.gnn import common, egnn, gatedgcn, mace, nequip  # noqa: F401
