from repro.models import common, moe, transformer  # noqa: F401
from repro.models import gnn, recsys  # noqa: F401
