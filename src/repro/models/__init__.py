"""LEGACY (seed-era training stack): unused by the SMSCC serving paper
reproduction.  Kept only so seed tests/examples keep importing; do not
extend -- the live system is repro.core / repro.api / repro.tenancy /
repro.launch.  See README "Legacy seed code".
"""
from repro.models import common, moe, transformer  # noqa: F401
from repro.models import gnn, recsys  # noqa: F401
