"""Decoder-only LM family: one scanned layer covers all five assigned archs.

Features (per-arch toggles in configs/): GQA with separate n_kv_heads,
explicit head_dim, qk-norm (qwen3), sliding-window attention (danube),
local:global layer interleave (gemma3 5:1), RoPE, RMSNorm, SwiGLU FFN or
MoE FFN (moonlight 64e/top-6 + shared expert, qwen3-moe 128e/top-8),
tied or untied vocab head.

Layers are homogeneous and *scanned* (params stacked on a leading [L] axis)
so the 94-layer dry-runs compile one layer once; per-layer structure (the
local/global pattern) rides along as a traced int32[L] window vector --
attention masks take the window as data, so no per-layer retrace happens.

Three entry points per the assigned shapes:
  ``loss_fn``      -- teacher-forced next-token CE          (train_4k)
  ``prefill``      -- build KV cache, return last logits    (prefill_32k)
  ``decode_step``  -- one token with a [L]-stacked KV cache (decode_32k,
                      long_500k for the bounded-window archs)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models import common, moe as moe_lib
from repro.kernels import flash_attention as fa

NEG_INF = -1e30


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int
    qk_norm: bool = False
    window: int = 0          # sliding-window width for local layers; 0=full
    local_global: int = 0    # N local layers per 1 global layer; 0=all global
    rope_theta: float = 1e4
    tie_embeddings: bool = True
    moe: Optional[moe_lib.MoEConfig] = None
    dtype: Any = jnp.float32
    remat: str = "none"      # 'none' | 'full' | 'dots' (§Perf knob)
    attn_impl: str = "xla"   # 'xla' | 'flash' (flash needs uniform windows)
    aux_loss_weight: float = 0.01
    # optional GSPMD constraint for the residual stream [B, S, D]
    # (Megatron-style sequence parallelism when S is on 'model'):
    act_spec: Any = None
    # unroll the layer scan (dry-run FLOP metering: XLA cost analysis
    # counts a while body once, ignoring trip count)
    scan_unroll: bool = False

    @property
    def windows(self):
        """int32[L] per-layer window (0 = full attention)."""
        out = []
        for l in range(self.n_layers):
            if self.local_global > 0 and \
                    (l + 1) % (self.local_global + 1) == 0:
                out.append(0)            # global layer
            else:
                out.append(self.window)  # local (or all-layer) window
        return jnp.asarray(out, jnp.int32)

    def n_params(self) -> int:
        d, dh = self.d_model, self.head_dim
        attn = d * dh * (self.n_heads * 2 + self.n_kv_heads * 2)
        if self.moe is not None:
            ffn = d * self.moe.n_experts * self.moe.d_ff * 3 + \
                d * self.moe.n_experts + \
                d * self.moe.d_ff * self.moe.n_shared_experts * 3
        else:
            ffn = 3 * d * self.d_ff
        per_layer = attn + ffn + 2 * d
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        return self.n_layers * per_layer + emb + d

    def n_active_params(self) -> int:
        """Parameters touched per token (MoE: only routed experts)."""
        if self.moe is None:
            return self.n_params()
        d = self.d_model
        attn = d * self.head_dim * (self.n_heads * 2 + self.n_kv_heads * 2)
        ffn = d * self.moe.top_k * self.moe.d_ff * 3 + \
            d * self.moe.n_experts + \
            d * self.moe.d_ff * self.moe.n_shared_experts * 3
        per_layer = attn + ffn + 2 * d
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        return self.n_layers * per_layer + emb + d


# --------------------------------------------------------------- params ---

def _layer_init(key, cfg: LMConfig):
    d, dh = cfg.d_model, cfg.head_dim
    ks = common.split_keys(
        key, ["wq", "wk", "wv", "wo", "ffn", "ln"])
    p = {
        "ln1": jnp.zeros((d,), cfg.dtype),
        "ln2": jnp.zeros((d,), cfg.dtype),
        "wq": common.dense_init(ks["wq"], (d, cfg.n_heads * dh),
                                dtype=cfg.dtype),
        "wk": common.dense_init(ks["wk"], (d, cfg.n_kv_heads * dh),
                                dtype=cfg.dtype),
        "wv": common.dense_init(ks["wv"], (d, cfg.n_kv_heads * dh),
                                dtype=cfg.dtype),
        "wo": common.dense_init(ks["wo"], (cfg.n_heads * dh, d),
                                dtype=cfg.dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((dh,), cfg.dtype)
        p["k_norm"] = jnp.zeros((dh,), cfg.dtype)
    if cfg.moe is not None:
        p["moe"] = moe_lib.init(ks["ffn"], cfg.moe, dtype=cfg.dtype)
    else:
        k1, k2, k3 = jax.random.split(ks["ffn"], 3)
        p["ffn"] = {
            "w_gate": common.dense_init(k1, (d, cfg.d_ff), dtype=cfg.dtype),
            "w_up": common.dense_init(k2, (d, cfg.d_ff), dtype=cfg.dtype),
            "w_down": common.dense_init(k3, (cfg.d_ff, d), dtype=cfg.dtype),
        }
    return p


def init(key, cfg: LMConfig):
    k_emb, k_layers, k_head = jax.random.split(key, 3)
    layer_keys = jax.random.split(k_layers, cfg.n_layers)
    layers = jax.vmap(lambda k: _layer_init(k, cfg))(layer_keys)
    params = {
        "embed": common.embed_init(k_emb, (cfg.vocab, cfg.d_model),
                                   dtype=cfg.dtype),
        "layers": layers,
        "ln_f": jnp.zeros((cfg.d_model,), cfg.dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = common.dense_init(
            k_head, (cfg.d_model, cfg.vocab), dtype=cfg.dtype)
    return params


# ------------------------------------------------------------ attention ---

def _heads(x, n, dh):
    b, s, _ = x.shape
    return x.reshape(b, s, n, dh)


def _attn_scores_mask(pos_q, pos_k, window):
    """bool mask [..., Sq, Sk]: causal ∧ (window==0 ∨ distance < window)."""
    d = pos_q[..., :, None] - pos_k[..., None, :]
    return (d >= 0) & ((window <= 0) | (d < window))


def _attention_xla(q, k, v, pos_q, pos_k, window):
    """q: [B,Sq,H,Dh]; k,v: [B,Sk,Hkv,Dh]; window: traced int32 scalar."""
    b, sq, h, dh = q.shape
    hkv = k.shape[2]
    rep = h // hkv
    qg = q.reshape(b, sq, hkv, rep, dh)
    scores = jnp.einsum("bqhrd,bkhd->bhrqk", qg, k).astype(jnp.float32)
    scores = scores / (dh ** 0.5)
    mask = _attn_scores_mask(pos_q, pos_k, window)  # [B,Sq,Sk] or [Sq,Sk]
    if mask.ndim == 2:
        mask = mask[None]
    scores = jnp.where(mask[:, None, None], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhrqk,bkhd->bqhrd", p, v)
    return out.reshape(b, sq, h, dh)


def _attention_chunked(q, k, v, pos_q, pos_k, window, chunk: int = 1024):
    """FlashAttention expressed in XLA: scan over KV chunks with an online
    softmax, so no [B,H,Sq,Sk] score tensor ever exists in HBM -- the
    §Perf lever for the memory-bound train/prefill cells.  Numerically
    identical to `_attention_xla` (same mask semantics, fp32 softmax).
    """
    b, sq, h, dh = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    rep = h // hkv
    if sk % chunk != 0:
        chunk = sk  # degenerate fallback (smoke shapes)
    nc = sk // chunk
    qg = (q.reshape(b, sq, hkv, rep, dh).astype(jnp.float32)
          / (dh ** 0.5))
    if pos_k.ndim == 1:
        pos_k = jnp.broadcast_to(pos_k[None], (b, sk))
    kc = k.reshape(b, nc, chunk, hkv, dh).swapaxes(0, 1)
    vc = v.reshape(b, nc, chunk, hkv, dh).swapaxes(0, 1)
    pc = pos_k.reshape(b, nc, chunk).swapaxes(0, 1)

    def body(carry, blk):
        m, l, acc = carry
        kb, vb, pkb = blk
        s = jnp.einsum("bqhrd,bkhd->bqhrk", qg, kb.astype(jnp.float32))
        mask = _attn_scores_mask(pos_q, pkb, window)   # [B, Sq, C]
        s = jnp.where(mask[:, :, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        p = jnp.where(mask[:, :, None, None], p, 0.0)
        corr = jnp.exp(m - m_new)
        l = l * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bqhrk,bkhd->bqhrd", p, vb.astype(jnp.float32))
        return (m_new, l, acc), None

    m0 = jnp.full((b, sq, hkv, rep), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, sq, hkv, rep), jnp.float32)
    a0 = jnp.zeros((b, sq, hkv, rep, dh), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (kc, vc, pc))
    out = acc / jnp.where(l == 0.0, 1.0, l)[..., None]
    return out.reshape(b, sq, h, dh).astype(q.dtype)


def _layer_fwd(cfg: LMConfig, p, x, positions, window, kv_override=None):
    """One decoder layer.  x: [B,S,D].  Returns (y, (k, v), aux_loss)."""
    b, s, d = x.shape
    dh = cfg.head_dim
    h = common.rms_norm(x, p["ln1"])
    q = _heads(h @ p["wq"], cfg.n_heads, dh)
    k = _heads(h @ p["wk"], cfg.n_kv_heads, dh)
    v = _heads(h @ p["wv"], cfg.n_kv_heads, dh)
    if cfg.qk_norm:
        q = common.rms_norm(q, p["q_norm"])
        k = common.rms_norm(k, p["k_norm"])
    q = common.rope(q.swapaxes(1, 2), positions[:, None, :],
                    cfg.rope_theta).swapaxes(1, 2)
    k = common.rope(k.swapaxes(1, 2), positions[:, None, :],
                    cfg.rope_theta).swapaxes(1, 2)
    if kv_override is not None:
        k_all, v_all, pos_k = kv_override(k, v)
    else:
        k_all, v_all, pos_k = k, v, positions
    if cfg.attn_impl == "flash" and kv_override is None \
            and cfg.local_global == 0:
        out = fa.mha(q.swapaxes(1, 2), k_all.swapaxes(1, 2),
                     v_all.swapaxes(1, 2), causal=True,
                     window=cfg.window).swapaxes(1, 2)
    elif cfg.attn_impl == "chunked":
        out = _attention_chunked(q, k_all, v_all, positions, pos_k, window)
    else:
        out = _attention_xla(q, k_all, v_all, positions, pos_k, window)
    x = x + out.reshape(b, s, cfg.n_heads * dh) @ p["wo"]
    h = common.rms_norm(x, p["ln2"])
    if cfg.moe is not None:
        y, aux = moe_lib.apply(p["moe"], h.reshape(b * s, d), cfg.moe)
        y = y.reshape(b, s, d)
    else:
        f = p["ffn"]
        y = (jax.nn.silu(h @ f["w_gate"]) * (h @ f["w_up"])) @ f["w_down"]
        aux = jnp.zeros((), jnp.float32)
    return x + y, (k, v), aux


def _scan_layers(cfg: LMConfig, params, x, positions, kv_override=None):
    windows = cfg.windows

    def body(carry, layer_in):
        x, aux = carry
        p, window = layer_in
        if cfg.act_spec is not None:
            x = jax.lax.with_sharding_constraint(x, cfg.act_spec)
        y, (k, v), a = _layer_fwd(cfg, p, x, positions, window, kv_override)
        if cfg.act_spec is not None:
            y = jax.lax.with_sharding_constraint(y, cfg.act_spec)
        return (y, aux + a), (k, v)

    if cfg.remat == "full":
        body = jax.checkpoint(body)
    elif cfg.remat == "dots":
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.checkpoint_dots)
    (x, aux), kv = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                                (params["layers"], windows),
                                unroll=bool(cfg.scan_unroll))
    return x, aux, kv


def _logits(cfg: LMConfig, params, x):
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return (x @ head).astype(jnp.float32)


# --------------------------------------------------------------- losses ---

def loss_fn(params, batch, cfg: LMConfig):
    """batch: {'tokens': int32[B,S], 'labels': int32[B,S] (-100 = pad)}."""
    tokens, labels = batch["tokens"], batch["labels"]
    b, s = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    x, aux, _ = _scan_layers(cfg, params, x, positions)
    x = common.rms_norm(x, params["ln_f"])
    logits = _logits(cfg, params, x)
    valid = labels >= 0
    tgt = jnp.maximum(labels, 0)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
    loss = jnp.sum(nll * valid) / jnp.maximum(jnp.sum(valid), 1)
    return loss + cfg.aux_loss_weight * aux / cfg.n_layers, {
        "ce": loss, "aux": aux}


# -------------------------------------------------------------- serving ---

def prefill(params, tokens, cfg: LMConfig, cache_len: int):
    """tokens: int32[B,S] -> (cache, last_logits [B,V]).

    cache = {'k','v': [L,B,cache_len,Hkv,Dh], 'pos': int32}.
    """
    b, s = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    x, _, kv = _scan_layers(cfg, params, x, positions)
    x = common.rms_norm(x, params["ln_f"])
    k, v = kv  # [L,B,S,Hkv,Dh]
    pad = cache_len - s
    k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    cache = {"k": k, "v": v, "pos": jnp.int32(s)}
    return cache, _logits(cfg, params, x[:, -1])


def decode_step(params, cache, tok, cfg: LMConfig):
    """One-token decode.  tok: int32[B] -> (logits [B,V], cache)."""
    b = tok.shape[0]
    cache_len = cache["k"].shape[2]
    pos = cache["pos"]
    x = jnp.take(params["embed"], tok[:, None], axis=0)  # [B,1,D]
    positions = jnp.broadcast_to(pos[None, None], (b, 1)).astype(jnp.int32)
    pos_k = jnp.arange(cache_len, dtype=jnp.int32)
    valid_k = pos_k <= pos  # written entries only

    windows = cfg.windows

    def body(carry, layer_in):
        x, = carry
        p, window, kc, vc = layer_in

        def kv_override(k_new, v_new):
            # write this step's k/v at position `pos`
            kk = jax.lax.dynamic_update_slice(
                kc, k_new, (0, pos, 0, 0))
            vv = jax.lax.dynamic_update_slice(
                vc, v_new, (0, pos, 0, 0))
            # mask out unwritten cache slots via key positions
            pk = jnp.where(valid_k, pos_k, jnp.int32(2 ** 30))
            return kk, vv, jnp.broadcast_to(pk[None], (b, cache_len))

        y, (k1, v1), _ = _layer_fwd(cfg, p, x, positions, window,
                                    kv_override)
        kk = jax.lax.dynamic_update_slice(kc, k1, (0, pos, 0, 0))
        vv = jax.lax.dynamic_update_slice(vc, v1, (0, pos, 0, 0))
        return (y,), (kk, vv)

    (x,), (k_new, v_new) = jax.lax.scan(
        body, (x,), (params["layers"], windows, cache["k"], cache["v"]),
        unroll=bool(cfg.scan_unroll))
    x = common.rms_norm(x, params["ln_f"])
    logits = _logits(cfg, params, x[:, 0])
    return logits, {"k": k_new, "v": v_new, "pos": pos + 1}
