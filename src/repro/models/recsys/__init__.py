from repro.models.recsys import mind  # noqa: F401
