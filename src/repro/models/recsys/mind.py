"""MIND (Li et al., arXiv:1904.08030): Multi-Interest Network with Dynamic
routing for sequential recommendation.

Pipeline: behavior-sequence item embeddings (the huge-table hot path) ->
B2I dynamic capsule routing into ``n_interests`` capsules -> label-aware
attention (training) or max-over-interests scoring (serving), with a
sampled-softmax loss.  The profile-feature side input goes through
EmbeddingBag (take + segment-sum -- the mandated construction; the Pallas
one-hot-matmul kernel is the TPU-optimized variant of the same op).

Embedding tables are row-sharded on the 'model' mesh axis at scale; lookups
become all-gather-style exchanges handled by GSPMD (see launch/partition).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.graph import segment_ops as so
from repro.models import common


@dataclasses.dataclass(frozen=True)
class MINDConfig:
    name: str = "mind"
    n_items: int = 2 ** 21          # embedding rows (10^6-scale mandate)
    embed_dim: int = 64
    seq_len: int = 50
    n_interests: int = 4
    capsule_iters: int = 3
    n_neg: int = 1024               # sampled-softmax negatives
    profile_vocab: int = 8192
    profile_len: int = 8
    pow_p: float = 2.0              # label-aware attention sharpness
    dtype: object = jnp.float32
    scan_unroll: bool = False


def init(key, cfg: MINDConfig):
    ks = common.split_keys(key, ["items", "profile", "bilinear", "binit",
                                 "proj"])
    d = cfg.embed_dim
    return {
        "item_embed": common.embed_init(ks["items"], (cfg.n_items, d),
                                        dtype=cfg.dtype),
        "profile_embed": common.embed_init(
            ks["profile"], (cfg.profile_vocab, d), dtype=cfg.dtype),
        # shared bilinear map S of B2I routing
        "S": common.dense_init(ks["bilinear"], (d, d), dtype=cfg.dtype),
        # fixed-at-init routing logit seed (breaks capsule symmetry)
        "b_init": (jax.random.normal(ks["binit"],
                                     (cfg.seq_len, cfg.n_interests))
                   * 1.0).astype(cfg.dtype),
        # fuse profile vector into each interest
        "proj": common.dense_init(ks["proj"], (2 * d, d), dtype=cfg.dtype),
    }


def _squash(v, axis=-1, eps=1e-9):
    n2 = jnp.sum(v * v, axis=axis, keepdims=True)
    n = jnp.sqrt(n2 + eps)
    return (n2 / (1.0 + n2)) * v / n


def interests(params, behavior, profile, cfg: MINDConfig):
    """behavior: int32[B, L] (-1 pad); profile: int32[B, P] (-1 pad)
    -> [B, K, D] interest capsules."""
    b, l = behavior.shape
    valid = (behavior >= 0)
    e = jnp.take(params["item_embed"], jnp.maximum(behavior, 0), axis=0)
    e = e * valid[..., None].astype(cfg.dtype)          # [B, L, D]
    e_s = e @ params["S"]                                # routed votes
    logits = jnp.broadcast_to(params["b_init"][None],
                              (b, l, cfg.n_interests))
    neg = jnp.asarray(-1e9, cfg.dtype)

    def routing_iter(logits, _):
        w = jax.nn.softmax(
            jnp.where(valid[..., None], logits, neg), axis=2)  # over K
        z = jnp.einsum("blk,bld->bkd", w, e_s)
        u = _squash(z)                                   # [B, K, D]
        logits = logits + jnp.einsum("bkd,bld->blk", u, e_s)
        return logits, u

    logits, us = jax.lax.scan(routing_iter, logits,
                              None, length=cfg.capsule_iters,
                              unroll=bool(cfg.scan_unroll))
    u = us[-1]
    # fuse profile bag (EmbeddingBag: take + segment reduction)
    pvec = so.embedding_bag(params["profile_embed"], profile, mode="mean")
    pk = jnp.broadcast_to(pvec[:, None, :], u.shape)
    u = jnp.tanh(jnp.concatenate([u, pk], -1) @ params["proj"])
    return u


def label_aware_user_vec(u, target_emb, cfg: MINDConfig):
    """Label-aware attention (training): soft-select interests by target."""
    att = jnp.einsum("bkd,bd->bk", u, target_emb)
    att = jax.nn.softmax(att * cfg.pow_p, axis=-1)
    return jnp.einsum("bk,bkd->bd", att, u)


def loss_fn(params, batch, cfg: MINDConfig):
    """batch: behavior [B,L], profile [B,P], target [B], negatives [N]."""
    u = interests(params, batch["behavior"], batch["profile"], cfg)
    tgt = jnp.take(params["item_embed"], batch["target"], axis=0)
    v = label_aware_user_vec(u, tgt, cfg)                # [B, D]
    neg = jnp.take(params["item_embed"], batch["negatives"], axis=0)
    pos_logit = jnp.sum(v * tgt, -1, keepdims=True)      # [B, 1]
    neg_logit = v @ neg.T                                # [B, N]
    logits = jnp.concatenate([pos_logit, neg_logit], -1).astype(jnp.float32)
    loss = -jnp.mean(jax.nn.log_softmax(logits, -1)[:, 0])
    acc = jnp.mean((jnp.argmax(logits, -1) == 0).astype(jnp.float32))
    return loss, {"ce": loss, "acc": acc}


def serve_score(params, batch, cfg: MINDConfig):
    """Online/offline scoring: max-over-interests dot with candidates.

    batch: behavior [B,L], profile [B,P], candidates [B,C] (or [1,C] with
    C ~ 10^6 for retrieval_cand -- one batched einsum, never a loop).
    """
    u = interests(params, batch["behavior"], batch["profile"], cfg)
    cand = jnp.take(params["item_embed"],
                    jnp.maximum(batch["candidates"], 0), axis=0)
    scores = jnp.einsum("bkd,bcd->bkc", u, cand)
    return jnp.max(scores, axis=1)                       # [B, C]


def retrieve_topk(params, batch, cfg: MINDConfig, k: int = 100):
    scores = serve_score(params, batch, cfg)
    return jax.lax.top_k(scores, k)
