"""Shared model primitives: norms, RoPE, inits.  Pure-function style --
params are plain pytrees of jnp arrays, every module is `init` + `apply`."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rms_norm(x, weight, eps: float = 1e-6):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + weight.astype(jnp.float32))).astype(dtype)


def rope(x, positions, theta: float = 10000.0):
    """Rotary embedding.  x: [..., S, D] with D even; positions: [..., S]."""
    d = x.shape[-1]
    half = d // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freq  # [..., S, half]
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


def dense_init(key, shape, scale: float | None = None, dtype=jnp.float32):
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    scale = scale if scale is not None else 1.0 / (fan_in ** 0.5)
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def embed_init(key, shape, dtype=jnp.float32):
    return (jax.random.normal(key, shape, jnp.float32)
            * (shape[-1] ** -0.5)).astype(dtype)


def split_keys(key, names):
    keys = jax.random.split(key, len(names))
    return dict(zip(names, keys))


def mlp_init(key, sizes, dtype=jnp.float32, bias: bool = True):
    """Plain MLP params: list of (w, b) between consecutive sizes."""
    layers = []
    keys = jax.random.split(key, len(sizes) - 1)
    for k, (a, b) in zip(keys, zip(sizes[:-1], sizes[1:])):
        w = dense_init(k, (a, b), dtype=dtype)
        layers.append({"w": w, "b": jnp.zeros((b,), dtype) if bias else None})
    return layers


def mlp_apply(layers, x, act=jax.nn.silu, final_act=None):
    n = len(layers)
    for i, lyr in enumerate(layers):
        x = x @ lyr["w"]
        if lyr["b"] is not None:
            x = x + lyr["b"]
        if i < n - 1:
            x = act(x)
        elif final_act is not None:
            x = final_act(x)
    return x


def count_params(params) -> int:
    return sum(int(x.size) for x in jax.tree_util.tree_leaves(params)
               if hasattr(x, "size"))
