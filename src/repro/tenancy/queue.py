"""Admission control for the multi-tenant engine: bounded work queue,
cross-tenant coalescing, and host-array transfer pooling.

The shape follows the serving front end in SNIPPETS §2 (SHARK-Engine's
``GenerateServiceV1``): a registry of compiled entry points behind a
bounded ``WorkQueue`` plus a ``TransferBufferPool`` so steady-state
submits allocate nothing.  Concretely:

* :class:`TransferBufferPool` -- freelists of bucketed host int32
  triples; ``submit`` copies the caller's (kind, u, v) into a pooled
  buffer and the flush returns it, so a hot submit path performs zero
  numpy allocations.
* :class:`WorkQueue` -- per-tenant FIFO of pending chunks with a global
  op budget.  A submit over budget is rejected immediately with
  :class:`QueueFull` carrying a ``retry_after`` hint (backpressure: the
  caller sheds load, the queue never grows unboundedly).  Admitted
  submits block on their ticket; the first waiter becomes the *flush
  leader*: it waits until either the coalescing budget fills
  (size-triggered) or its deadline lapses (deadline-triggered), then
  drains the queue in **waves** -- one head-of-line chunk per tenant per
  wave -- through the engine callback.  Waves keep the single-tenant
  chunk-boundary semantics (generation and compaction cadence) while
  letting T tenants' chunks share one vmapped dispatch.
"""
from __future__ import annotations

import threading
import time
from collections import OrderedDict, deque
from typing import Callable, Dict, Optional, Sequence

import numpy as np

from repro.fault.errors import DeadlineExceeded, Unavailable
from repro.fault.inject import maybe_stall

__all__ = ["QueueFull", "TransferBufferPool", "WorkQueue"]


class QueueFull(Unavailable):
    """Backpressure: the queue's op budget is exhausted.  ``retry_after``
    is the seconds the caller should wait before resubmitting (one flush
    deadline: by then the leader has drained the backlog).  A member of
    the :mod:`repro.fault.errors` taxonomy (``Unavailable``), so the
    ``GraphClient`` retry loop handles it like any transient refusal."""

    def __init__(self, retry_after: float):
        super().__init__(f"work queue full; retry after {retry_after}s",
                         retry_after=retry_after)


class _Buffers:
    __slots__ = ("kind", "u", "v", "cap")

    def __init__(self, cap: int):
        self.cap = cap
        self.kind = np.empty(cap, np.int32)
        self.u = np.empty(cap, np.int32)
        self.v = np.empty(cap, np.int32)


class TransferBufferPool:
    """Bucketed freelists of host (kind, u, v) int32 triples.

    ``acquire(n)`` hands back a buffer of the smallest bucket >= n
    (allocating only on a cold freelist); ``release`` returns it.  An
    oversized request falls through to a one-off exact allocation
    (counted as a miss, never pooled)."""

    def __init__(self, buckets: Sequence[int] = (64, 256, 1024, 4096),
                 per_bucket: int = 16):
        self.buckets = tuple(sorted({int(b) for b in buckets}))
        assert self.buckets and all(b > 0 for b in self.buckets)
        self._per_bucket = per_bucket
        self._free: Dict[int, list] = {b: [] for b in self.buckets}
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0

    def acquire(self, n: int) -> _Buffers:
        fits = [b for b in self.buckets if b >= n]
        if not fits:
            with self._lock:
                self.misses += 1
            return _Buffers(n)
        b = fits[0]
        with self._lock:
            free = self._free[b]
            if free:
                self.hits += 1
                return free.pop()
            self.misses += 1
        return _Buffers(b)

    def release(self, buf: _Buffers):
        with self._lock:
            free = self._free.get(buf.cap)
            if free is not None and len(free) < self._per_bucket:
                free.append(buf)

    def stats(self) -> dict:
        with self._lock:
            return {"hits": self.hits, "misses": self.misses,
                    "pooled": sum(len(f) for f in self._free.values())}


class _Ticket:
    __slots__ = ("tid", "buf", "n", "t_submit", "event", "ok", "gen",
                 "error")

    def __init__(self, tid: str, buf: _Buffers, n: int, t_submit: float):
        self.tid = tid
        self.buf = buf
        self.n = n
        self.t_submit = t_submit
        self.event = threading.Event()
        self.ok = None
        self.gen = None
        self.error: Optional[Exception] = None


class WorkQueue:
    """Bounded, coalescing admission queue in front of an engine apply
    callback (``apply_fn(requests) -> {tid: (ok, gen) | Exception}``).

    * ``max_pending_ops`` -- global op budget; over-budget submits raise
      :class:`QueueFull` (reject-with-retry-after, never block-and-grow).
    * ``coalesce_ops`` -- size trigger: the leader flushes as soon as
      this many ops are queued.
    * ``flush_deadline_s`` -- latency bound: the leader flushes no later
      than this after its own enqueue, however few tenants showed up.
      0 means flush immediately (no coalescing window).

    There is no dispatcher thread: the first blocked submitter *is* the
    dispatcher (leader), so an idle queue costs nothing and shutdown is
    trivial.  ``flush()`` drains synchronously (tests / checkpoints).
    """

    def __init__(self, apply_fn: Callable, *,
                 max_pending_ops: int = 8192,
                 coalesce_ops: int = 1024,
                 flush_deadline_s: float = 0.002,
                 pool: TransferBufferPool | None = None,
                 latency_window: int = 512):
        self._apply_fn = apply_fn
        self._max_pending_ops = max_pending_ops
        self._coalesce_ops = coalesce_ops
        self._flush_deadline_s = flush_deadline_s
        self.pool = pool or TransferBufferPool()
        self._cv = threading.Condition()
        self._pending: "OrderedDict[str, deque]" = OrderedDict()
        self._pending_ops = 0
        self._leader_active = False
        self._latency: Dict[str, deque] = {}
        self._latency_window = latency_window
        self.rejects = 0
        self.flush_causes = {"size": 0, "deadline": 0, "explicit": 0}
        self.waves = 0
        self.depth_max = 0
        self.submitted = 0

    # -------------------------------------------------------------- submit

    def submit(self, tid: str, kind, u, v,
               timeout: float | None = None):
        """Enqueue one chunk for ``tid`` and block for its result:
        ``(ok bool[n], gen int)``.  Raises :class:`QueueFull` under
        backpressure, or the engine's per-tenant error (all-or-nothing:
        a failed chunk left the tenant untouched)."""
        kind = np.asarray(kind, np.int32)
        n = kind.shape[0]
        now = time.perf_counter()
        with self._cv:
            if self._pending_ops + n > self._max_pending_ops:
                self.rejects += 1
                raise QueueFull(retry_after=max(self._flush_deadline_s,
                                                1e-3))
            buf = self.pool.acquire(n)
            buf.kind[:n] = kind
            buf.u[:n] = np.asarray(u, np.int32)
            buf.v[:n] = np.asarray(v, np.int32)
            tk = _Ticket(tid, buf, n, now)
            self._pending.setdefault(tid, deque()).append(tk)
            self._pending_ops += n
            self.submitted += 1
            self.depth_max = max(self.depth_max, self._pending_ops)
            lead = not self._leader_active
            if lead:
                self._leader_active = True
            elif self._pending_ops >= self._coalesce_ops:
                self._cv.notify_all()   # wake the waiting leader early
        if lead:
            self._lead(tk)
        if not tk.event.wait(timeout):
            raise DeadlineExceeded(
                f"chunk for tenant {tid!r} not flushed within {timeout}s"
                f" (result may still land; do not blind-retry)")
        if tk.error is not None:
            raise tk.error
        return tk.ok, tk.gen

    def depth(self) -> int:
        with self._cv:
            return self._pending_ops

    # --------------------------------------------------------------- flush

    def flush(self):
        """Drain everything now (synchronous; used by tests, eviction,
        and checkpointing).  If a leader is mid-flight, wait it out."""
        with self._cv:
            while self._leader_active:
                self._cv.wait(0.01)
            if not self._pending:
                return
            self._leader_active = True
        self._drain("explicit")

    def _lead(self, tk: _Ticket):
        deadline = tk.t_submit + self._flush_deadline_s
        cause = "deadline"
        with self._cv:
            while self._pending_ops < self._coalesce_ops:
                remaining = deadline - time.perf_counter()
                if remaining <= 0:
                    break
                self._cv.wait(remaining)
            if self._pending_ops >= self._coalesce_ops:
                cause = "size"
        self._drain(cause)

    def _drain(self, cause: str):
        """Leader loop: one head-of-line chunk per tenant per wave, until
        the queue is empty; then hand leadership back."""
        self.flush_causes[cause] += 1
        maybe_stall("queue_wave")
        while True:
            with self._cv:
                wave = []
                for tid, q in list(self._pending.items()):
                    t = q.popleft()
                    wave.append(t)
                    if not q:
                        del self._pending[tid]
                for t in wave:
                    self._pending_ops -= t.n
                if not wave:
                    self._leader_active = False
                    self._cv.notify_all()
                    return
            try:
                results = self._apply_fn(
                    [(t.tid, t.buf.kind[:t.n], t.buf.u[:t.n],
                      t.buf.v[:t.n]) for t in wave])
            except Exception as e:      # engine-level failure: fail wave
                results = {t.tid: e for t in wave}
            t_done = time.perf_counter()
            for t in wave:
                res = results.get(t.tid)
                if isinstance(res, Exception) or res is None:
                    t.error = res or RuntimeError(
                        f"engine returned no result for {t.tid!r}")
                else:
                    t.ok, t.gen = res
                lat = self._latency.setdefault(
                    t.tid, deque(maxlen=self._latency_window))
                lat.append(t_done - t.t_submit)
                self.pool.release(t.buf)
                t.event.set()
            self.waves += 1

    # --------------------------------------------------------------- stats

    def latency_quantiles(self, tid: str) -> dict:
        """p50/p95 submit->resolve latency (seconds) over the sliding
        window, the serving-fairness axis the bench tracks per tenant."""
        lat = self._latency.get(tid)
        if not lat:
            return {"p50_s": None, "p95_s": None, "samples": 0}
        arr = np.asarray(lat)
        return {"p50_s": round(float(np.percentile(arr, 50)), 6),
                "p95_s": round(float(np.percentile(arr, 95)), 6),
                "samples": int(arr.shape[0])}

    def stats(self) -> dict:
        with self._cv:
            return {
                "depth_ops": self._pending_ops,
                "depth_max_ops": self.depth_max,
                "max_pending_ops": self._max_pending_ops,
                "coalesce_ops": self._coalesce_ops,
                "flush_deadline_s": self._flush_deadline_s,
                "submitted": self.submitted,
                "rejects": self.rejects,
                "waves": self.waves,
                "flush_causes": dict(self.flush_causes),
                "pool": self.pool.stats(),
            }
