"""Vmapped multi-tenant engine: many small graphs, one compiled step.

The paper serves ONE shared-memory graph; production traffic is thousands
of independent session graphs.  The concurrent-graph line of work this
repo follows gets its throughput by composing many small linearizable
structures under one object -- the JAX analogue is stacking per-tenant
:class:`~repro.core.graph_state.GraphState` pytrees along a leading
*tenant axis* and running the already-compiled 5-phase scan step under
``jax.vmap``: T tenants' same-shape super-chunks cost one dispatch and
one deferred host transfer instead of T.

Design rules (all load-bearing for the differential oracle test):

* **Same scheduler, same gens.**  Each tenant's chunk is cut by the very
  same :class:`~repro.launch.stream.BucketedScheduler` plan and
  scan-length registry a single-tenant :class:`SCCService` would use, so
  the per-tenant generation trajectory (one bump per plan entry) is
  bit-identical to the oracle's.  Idle tenants are never stepped.
* **Per-lane fault isolation.**  Overflow and ``RepairStats`` outputs
  stay per-lane.  A lane that overflows anywhere in its chunk is
  discarded wholesale and the chunk replays *solo* through a throwaway
  ``SCCService`` seeded with the tenant's pre-state and the engine's
  decision knobs -- literally the oracle's own grow-and-replay code, so
  growth escalation, replay gens, and table layout match the
  single-tenant service decision-for-decision.  Other lanes commit from
  the shared dispatch untouched.
* **Capacity groups.**  ``vmap`` needs one static config per dispatch,
  so tenants are grouped by their current :class:`GraphConfig`; a grown
  tenant migrates to the group of its new capacity.  Per-tenant edge
  capacities therefore always come from the shared growth ladder
  (``boot capacity x grow_factor^k``) -- the bucket-registry discipline
  applied to the tenant axis.
* **Bounded compiles.**  Tenant batches are padded to a small registry
  (``tenant_batches``) exactly like op chunks are padded to ``buckets``;
  compiled update entries are keyed ``(tenant_batch, scan_len, bucket,
  cfg)`` and the registry asserts the
  ``len(tenant_batches) x len(scan_lengths) x len(buckets)``-per-config
  bound on every insertion.
* **Compaction cadence.**  The oracle checks tombstone pressure after
  every chunk; the engine replicates that with ONE vmapped
  ``fill_stats`` over the flushed lanes (amortized into the flush's
  single host sync) and compacts over-threshold lanes through the same
  throwaway-service path.

Engine parity with the oracle assumes ``proactive_grow=False`` (the
service default): proactive growth is a heuristic that changes *when*
capacity is minted, and the engine intentionally keeps the reactive
grow-and-replay backstop as the only growth path.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from functools import partial
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dynamic, edge_table as et, graph_state as gs
from repro.core.service import SCCService, _ids_in_range
from repro.launch.stream import BucketedScheduler

__all__ = ["TenantEngine"]


# --------------------------------------------------------------- jit entries

@partial(jax.jit, static_argnames=("cfg",))
def _vmapped_scan(states, ops, cfg):
    """vmap of the fused K-step scan over a leading tenant axis.

    states: GraphState pytree with leading [T] axis; ops: OpBatch with
    int32[T, K, B] leaves.  Returns (states', ok bool[T, K, B],
    ovf int32[T, K], RepairStats int32[T, K]) -- overflow and repair
    telemetry stay per-lane, which is what keeps one tenant's doom from
    touching another's commit.
    """
    return jax.vmap(
        lambda s, o: dynamic._apply_batch_scan_impl(s, o, cfg))(states, ops)


@jax.jit
def _vmapped_fill_stats(tables):
    """(live, tomb) int32[T] over a stacked edge-table pytree."""
    return jax.vmap(et.fill_stats)(tables)


@jax.jit
def _vmapped_same_scc(states, u, v):
    """bool[T, Q]: per-tenant checkSCC batches in one dispatch."""
    from repro.core import community
    return jax.vmap(community.check_scc)(states, u, v)


@jax.jit
def _vmapped_community_of(states, u):
    """int32[T, Q]: per-tenant blongsToCommunity in one dispatch."""
    from repro.core import community
    return jax.vmap(community.belongs_to_community)(states, u)


def _stack(trees):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def _lane(tree, i: int):
    return jax.tree.map(lambda a: a[i], tree)


def _set_lane(tree, i: int, lane):
    return jax.tree.map(lambda a, x: a.at[i].set(x), tree, lane)


# ------------------------------------------------------------- bookkeeping

@dataclasses.dataclass
class _Tenant:
    tid: str
    cfg: gs.GraphConfig        # current capacity group key
    lane: int                  # lane index inside the group's stack
    gen: int                   # host-tracked committed generation
    applied_chunks: int = 0
    fallback_chunks: int = 0
    grow_count: int = 0
    replayed_ops: int = 0
    compaction_count: int = 0


class _Group:
    """One capacity class: a stacked GraphState plus its lane map."""

    def __init__(self, cfg: gs.GraphConfig):
        self.cfg = cfg
        self.states = None            # stacked pytree, leading [L] axis
        self.lanes: List[Optional[str]] = []   # lane -> tid (None = free)

    @property
    def used(self) -> int:
        return sum(1 for t in self.lanes if t is not None)


class _Work:
    """Per-tenant in-flush scratch: piece queue + transfer refs."""

    def __init__(self, tenant: _Tenant, kind, u, v, pieces):
        self.t = tenant
        self.kind, self.u, self.v = kind, u, v
        self.pieces = pieces          # [(slices, np kind/u/v [K, B])]
        self.pos = 0
        self.row = 0                  # row inside the flush's [W] stack
        self.refs = []                # [(slices, xfer index, batch row)]
        self.error: Optional[Exception] = None
        self.ok: Optional[np.ndarray] = None
        self.compacted_solo = False   # fallback path ran _maybe_compact


class TenantEngine:
    """Stacked-lane executor under :class:`MultiTenantService`.

    Holds every tenant's committed state in per-capacity-class stacked
    arrays and applies one chunk per tenant per :meth:`apply_chunks`
    call as rounds of vmapped fused-scan dispatches with ONE host sync
    per capacity group.  Not a public API: the service layer owns
    admission, durability, and the typed client surface.
    """

    def __init__(self, *, buckets: Sequence[int] = (64, 256, 1024),
                 scan_lengths: Sequence[int] = (1, 4, 16),
                 tenant_batches: Sequence[int] = (1, 2, 4, 8),
                 grow_factor: int = 2,
                 max_edge_capacity: int | None = None,
                 compact_tomb_frac: float = 0.25):
        self._sched = BucketedScheduler(buckets)
        self._scan_lengths = tuple(sorted({int(s) for s in scan_lengths}
                                          | {1}))
        self._tenant_batches = tuple(sorted({int(t)
                                             for t in tenant_batches}))
        assert self._tenant_batches and all(t > 0
                                            for t in self._tenant_batches)
        self._grow_factor = grow_factor
        self._max_edge_capacity = max_edge_capacity
        self._compact_tomb_frac = compact_tomb_frac
        self._groups: Dict[gs.GraphConfig, _Group] = {}
        self._tenants: Dict[str, _Tenant] = {}
        # one lock serializes all structural mutation; queries extract
        # committed lanes under it (group states only move at flush end)
        self._lock = threading.RLock()
        self._commit_cv = threading.Condition(self._lock)
        # compiled-entry registries (update entries are the bounded ones;
        # query/fill-stats entries are separately cached, like the
        # service's query shapes)
        self._compiled: set = set()
        self._query_compiled: set = set()
        self._cfgs_minted: set = set()
        self.flush_count = 0
        self.solo_replays = 0

    # ------------------------------------------------------------ registry

    @property
    def compile_count(self) -> int:
        """Distinct vmapped update-step entries dispatched so far."""
        return len(self._compiled)

    @property
    def compile_bound(self) -> int:
        """The asserted ceiling: ``tenant_batches x scan_lengths x
        buckets`` per minted capacity class (mirrors the single-tenant
        ``buckets x (scan_lengths + 1)``-per-config discipline)."""
        return (len(self._tenant_batches) * len(self._scan_lengths)
                * len(self._sched.buckets)
                * max(1, len(self._cfgs_minted)))

    def _register_entry(self, tb: int, k: int, b: int,
                        cfg: gs.GraphConfig):
        key = (tb, k, b, cfg)
        if key in self._compiled:
            return
        self._cfgs_minted.add(cfg)
        self._compiled.add(key)
        assert len(self._compiled) <= self.compile_bound, (
            f"per-flush recompilation detected: {len(self._compiled)} "
            f"vmapped step entries exceed the "
            f"{len(self._tenant_batches)} tenant batches x "
            f"{len(self._scan_lengths)} scan lengths x "
            f"{len(self._sched.buckets)} buckets x "
            f"{len(self._cfgs_minted)} configs bound")

    def _pick_tenant_batch(self, n: int) -> int:
        fits = [t for t in self._tenant_batches if t >= n]
        return fits[0] if fits else self._tenant_batches[-1]

    # ---------------------------------------------------------- tenant CRUD

    def create_tenant(self, tid: str, cfg: gs.GraphConfig,
                      state: gs.GraphState | None = None,
                      gen: int | None = None):
        """Give ``tid`` a lane.  ``state``/``gen`` rehydrate an evicted
        tenant; fresh tenants boot ``gs.empty(cfg)`` at gen 0, exactly a
        fresh ``SCCService(cfg)``."""
        with self._lock:
            assert tid not in self._tenants, f"tenant {tid!r} exists"
            if state is None:
                state = gs.empty(cfg)
            lane = self._add_lane(cfg, state, tid)
            self._tenants[tid] = _Tenant(
                tid=tid, cfg=cfg, lane=lane,
                gen=int(state.gen) if gen is None else int(gen))

    def remove_tenant(self, tid: str) -> Tuple[gs.GraphState,
                                               gs.GraphConfig, int]:
        """Extract ``tid``'s lane and compact it out of the stack.
        Returns (state, cfg, gen) so the caller can snapshot or drop."""
        with self._lock:
            t = self._tenants.pop(tid)
            group = self._groups[t.cfg]
            state = _lane(group.states, t.lane)
            group.lanes[t.lane] = None
            self._compact_group(group)
            return state, t.cfg, t.gen

    def has_tenant(self, tid: str) -> bool:
        with self._lock:
            return tid in self._tenants

    def tenant_ids(self) -> List[str]:
        with self._lock:
            return list(self._tenants)

    def tenant_state(self, tid: str) -> gs.GraphState:
        """Committed snapshot of one tenant (lane extraction)."""
        with self._lock:
            t = self._tenants[tid]
            return _lane(self._groups[t.cfg].states, t.lane)

    def tenant_cfg(self, tid: str) -> gs.GraphConfig:
        with self._lock:
            return self._tenants[tid].cfg

    def tenant_gen(self, tid: str) -> int:
        with self._lock:
            return self._tenants[tid].gen

    def wait_for_gen(self, tid: str, gen: int,
                     timeout: float | None = None) -> int:
        """Block until ``tid``'s committed generation reaches ``gen``."""
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._commit_cv:
            while tid in self._tenants and self._tenants[tid].gen < gen:
                if deadline is None:
                    self._commit_cv.wait()
                else:
                    remaining = deadline - time.monotonic()
                    if remaining <= 0:
                        break
                    self._commit_cv.wait(remaining)
            return self._tenants[tid].gen if tid in self._tenants else -1

    def tenant_telemetry(self, tid: str) -> dict:
        with self._lock:
            t = self._tenants[tid]
            return {
                "gen": t.gen,
                "edge_capacity": t.cfg.edge_capacity,
                "applied_chunks": t.applied_chunks,
                "fallback_chunks": t.fallback_chunks,
                "grows": t.grow_count,
                "replayed_ops": t.replayed_ops,
                "compactions": t.compaction_count,
            }

    def occupancy(self) -> dict:
        """Lane-occupancy telemetry per capacity class."""
        with self._lock:
            groups = {g.cfg.edge_capacity: {"lanes": len(g.lanes),
                                            "used": g.used}
                      for g in self._groups.values()}
            lanes = sum(v["lanes"] for v in groups.values())
            used = sum(v["used"] for v in groups.values())
            return {"tenants": len(self._tenants),
                    "lanes": lanes, "used": used,
                    "frac": round(used / lanes, 4) if lanes else 1.0,
                    "by_capacity": groups}

    # ------------------------------------------------------- lane plumbing

    def _add_lane(self, cfg: gs.GraphConfig, state: gs.GraphState,
                  tid: str) -> int:
        group = self._groups.get(cfg)
        if group is None:
            group = self._groups[cfg] = _Group(cfg)
        if group.states is None:
            group.states = _stack([state])
            group.lanes = [tid]
            return 0
        for i, owner in enumerate(group.lanes):
            if owner is None:
                group.states = _set_lane(group.states, i, state)
                group.lanes[i] = tid
                return i
        # full: append exactly one lane.  Tenant creation is control-
        # plane-rare, and keeping groups PACKED is what lets the steady
        # flush run on ``group.states`` in place -- a free tail lane
        # would force a gather/scatter round trip on every wave.
        n = len(group.lanes)
        group.states = jax.tree.map(
            lambda a, x: jnp.concatenate([a, x[None]]),
            group.states, state)
        group.lanes.append(tid)
        return n

    def _compact_group(self, group: _Group):
        """Repack live lanes to the front and shrink the stack -- the
        eviction path's promise that a cold tenant's arrays are actually
        released, not just masked."""
        live = [i for i, t in enumerate(group.lanes) if t is not None]
        if not live:
            del self._groups[group.cfg]
            return
        if live == list(range(len(group.lanes))):
            return
        idx = jnp.asarray(np.asarray(live, np.int32))
        group.states = jax.tree.map(lambda a: a[idx], group.states)
        for new_lane, old_lane in enumerate(live):
            self._tenants[group.lanes[old_lane]].lane = new_lane
        group.lanes = [group.lanes[i] for i in live]

    def _move_tenant(self, t: _Tenant, new_cfg: gs.GraphConfig,
                     state: gs.GraphState):
        old = self._groups[t.cfg]
        old.lanes[t.lane] = None
        t.cfg = new_cfg
        t.lane = self._add_lane(new_cfg, state, t.tid)
        self._compact_group(old)

    # --------------------------------------------------------- super-chunks

    def _pack_super_chunks(self, kind, u, v):
        """Host-array mirror of ``BucketedScheduler.super_chunks``: the
        identical plan/grouping, but numpy leaves (so cross-tenant
        stacking costs no device round-trip)."""
        lens = self._scan_lengths
        plan = self._sched.plan(kind.shape[0])
        pieces, i = [], 0
        while i < len(plan):
            b = plan[i][1]
            j = i
            while j < len(plan) and plan[j][1] == b:
                j += 1
            while i < j:
                k = max(s for s in lens if s <= j - i)
                group = plan[i:i + k]
                pk = np.full((k, b), dynamic.NOP, np.int32)
                pu = np.zeros((k, b), np.int32)
                pv = np.zeros((k, b), np.int32)
                for r, (sl, _) in enumerate(group):
                    n = sl.stop - sl.start
                    pk[r, :n] = kind[sl]
                    pu[r, :n] = u[sl]
                    pv[r, :n] = v[sl]
                pieces.append(([sl for sl, _ in group], pk, pu, pv))
                i += k
        return pieces

    # -------------------------------------------------------------- updates

    def apply_chunks(self, requests):
        """Apply one chunk per tenant: ``[(tid, kind, u, v), ...]``.

        Returns ``{tid: (ok bool[N], gen int) | Exception}`` -- a failed
        tenant (capacity cap) rolls back all-or-nothing without touching
        any other lane.  A tenant may appear at most once per call; the
        admission queue feeds head-of-line chunks in waves to keep the
        oracle's chunk-boundary compaction cadence.
        """
        out: Dict[str, object] = {}
        with self._lock:
            by_cfg: Dict[gs.GraphConfig, List[_Work]] = {}
            seen = set()
            for tid, kind, u, v in requests:
                assert tid not in seen, f"duplicate chunk for {tid!r}"
                seen.add(tid)
                t = self._tenants[tid]
                kind = np.asarray(kind, np.int32)
                u = np.asarray(u, np.int32)
                v = np.asarray(v, np.int32)
                if kind.shape[0] == 0:
                    out[tid] = (np.zeros(0, bool), t.gen)
                    continue
                w = _Work(t, kind, u, v,
                          self._pack_super_chunks(kind, u, v))
                by_cfg.setdefault(t.cfg, []).append(w)
            for cfg, works in by_cfg.items():
                self._apply_cfg_group(cfg, works, out)
            self.flush_count += 1
            self._commit_cv.notify_all()
        return out

    def _apply_cfg_group(self, cfg: gs.GraphConfig, works: List[_Work],
                         out: dict):
        # The flush works on ONE [W]-stacked scratch pytree (`cur`) and
        # moves data by whole-batch gather/scatter, never by per-lane
        # slicing: eager per-lane ops (`a[i]`, `a.at[i].set`) cost a
        # dispatch per leaf per tenant and would eat the coalescing win
        # on CPU.  In the steady serving shape -- every lane of the
        # group flushes and the wave matches a registered tenant batch
        # -- `cur` IS `group.states` and a round is exactly one vmapped
        # dispatch with zero data movement.
        group = self._groups[cfg]
        works = sorted(works, key=lambda w: w.t.lane)
        for r, w in enumerate(works):
            w.row = r
        lanes = [w.t.lane for w in works]
        whole = lanes == list(range(len(group.lanes)))
        if whole:
            cur = group.states
        else:
            lidx = jnp.asarray(np.asarray(lanes, np.int32))
            cur = jax.tree.map(lambda a: a[lidx], group.states)
        n_rows = len(works)
        xfers: List[tuple] = []       # [(ok [tb,K,B], ovf [tb,K])]
        # --- rounds of vmapped dispatches (async; no host sync) -------
        while True:
            active = [w for w in works if w.pos < len(w.pieces)]
            if not active:
                break
            shapes: Dict[Tuple[int, int], List[_Work]] = {}
            for w in active:
                k, b = w.pieces[w.pos][1].shape
                shapes.setdefault((k, b), []).append(w)
            for (k, b), ws in shapes.items():
                i = 0
                while i < len(ws):
                    tb = self._pick_tenant_batch(len(ws) - i)
                    cur = self._dispatch(cfg, k, b, tb, ws[i:i + tb],
                                         cur, n_rows, xfers)
                    i += tb
            for w in active:
                w.pos += 1
        # --- compaction probe, amortized into the one sync ------------
        live_tomb = _vmapped_fill_stats(cur.edges)
        # --- the flush's single host transfer --------------------------
        host_xfers, (live, tomb) = jax.device_get((xfers, live_tomb))
        # --- per-lane commit / solo replay -----------------------------
        fast: List[_Work] = []
        for w in works:
            host_pieces = [(host_xfers[xi][0][r], host_xfers[xi][1][r])
                           for _, xi, r in w.refs]
            total_ovf = sum(int(np.sum(ovf)) for _, ovf in host_pieces)
            if total_ovf == 0:
                ok = np.zeros(w.kind.shape[0], bool)
                steps = 0
                for (slices, _, _), (ok_kb, _) in zip(w.refs,
                                                      host_pieces):
                    for j, sl in enumerate(slices):
                        ok[sl] = ok_kb[j, :sl.stop - sl.start]
                    steps += len(slices)
                w.ok = ok
                w.t.gen += steps
                w.t.applied_chunks += 1
                fast.append(w)
            else:
                self._solo_replay(cfg, w)
        # --- commit fast-path rows back into the stack -----------------
        if fast:
            if whole and len(fast) == n_rows:
                group.states = cur
            else:
                frows = jnp.asarray(np.asarray([w.row for w in fast],
                                               np.int32))
                flanes = jnp.asarray(np.asarray(
                    [w.t.lane for w in fast], np.int32))
                group.states = jax.tree.map(
                    lambda g, c: g.at[flanes].set(c[frows]),
                    group.states, cur)
        # --- oracle-cadence compaction (post-chunk tombstone check) ----
        for w, work_live, work_tomb in zip(works, live, tomb):
            if w.error is not None or w.compacted_solo:
                continue
            if int(work_tomb) > self._compact_tomb_frac * \
                    w.t.cfg.edge_capacity:
                self._compact_tenant(w.t)
        for w in works:
            out[w.t.tid] = w.error if w.error is not None \
                else (w.ok, w.t.gen)

    def _dispatch(self, cfg: gs.GraphConfig, k: int, b: int, tb: int,
                  ws: List[_Work], cur, n_rows: int, xfers: list):
        """One vmapped fused-scan step over ≤ tb tenants' current pieces
        (padded to the registered tenant batch with NOP lanes).  Gathers
        the participating rows out of the [W]-stacked ``cur``, scatters
        the results back, and returns the new ``cur``; a full-coverage
        dispatch (every row, exact registered batch) runs on ``cur``
        in place with no gather or scatter at all."""
        self._register_entry(tb, k, b, cfg)
        rows = [w.row for w in ws]
        full = tb == n_rows and rows == list(range(n_rows))
        if full:
            sub = cur
        else:
            ridx = rows + [rows[0]] * (tb - len(rows))
            sub = jax.tree.map(
                lambda a: a[jnp.asarray(np.asarray(ridx, np.int32))],
                cur)
        pk = np.full((tb, k, b), dynamic.NOP, np.int32)
        pu = np.zeros((tb, k, b), np.int32)
        pv = np.zeros((tb, k, b), np.int32)
        for i, w in enumerate(ws):
            _, wk, wu, wv = w.pieces[w.pos]
            pk[i], pu[i], pv[i] = wk, wu, wv
        ops = dynamic.make_ops(pk, pu, pv)
        new_states, ok, ovf, _ = _vmapped_scan(sub, ops, cfg)
        if full:
            cur = new_states
        else:
            sidx = jnp.asarray(np.asarray(rows, np.int32))
            keep = new_states if len(ws) == tb else jax.tree.map(
                lambda n: n[:len(ws)], new_states)
            cur = jax.tree.map(lambda c, n: c.at[sidx].set(n),
                               cur, keep)
        xi = len(xfers)
        xfers.append((ok, ovf))
        for i, w in enumerate(ws):
            w.refs.append((w.pieces[w.pos][0], xi, i))
        return cur

    def _shadow_service(self, cfg: gs.GraphConfig,
                        state: gs.GraphState) -> SCCService:
        """The oracle's own code path, seeded with one tenant's lane:
        every non-fast-path decision (growth escalation, replay,
        compaction) is delegated here so it matches a single-tenant
        service decision-for-decision."""
        return SCCService(cfg, buckets=self._sched.buckets, state=state,
                          grow_factor=self._grow_factor,
                          max_edge_capacity=self._max_edge_capacity,
                          compact_tomb_frac=self._compact_tomb_frac,
                          inflight_window=0, donate=False,
                          scan_lengths=self._scan_lengths,
                          proactive_grow=False)

    def _solo_replay(self, cfg: gs.GraphConfig, w: _Work):
        """A doomed lane's chunk re-runs alone through grow-and-replay.

        The lane's vmapped outputs are discarded (its stack slot still
        holds the pre-chunk state, since fast-path scatter happens
        after); the shadow service replays the WHOLE chunk serially from
        that pre-state -- the same restart the single-tenant fallback
        performs -- then the grown/compacted result re-enters whichever
        capacity group now matches.
        """
        self.solo_replays += 1
        t = w.t
        pre = _lane(self._groups[cfg].states, t.lane)
        svc = self._shadow_service(cfg, pre)
        try:
            ok = svc._apply_chunk(w.kind, w.u, w.v)
        except Exception as e:          # capacity cap: lane unchanged
            t.fallback_chunks += 1
            w.error = e
            return
        t.fallback_chunks += 1
        t.grow_count += svc.grow_count
        t.replayed_ops += svc.replayed_ops
        t.compaction_count += svc.compaction_count
        t.gen = svc.gen
        t.applied_chunks += 1
        w.ok = ok
        w.compacted_solo = True         # shadow ran _maybe_compact
        if svc.cfg != cfg:
            self._move_tenant(t, svc.cfg, svc.state)
        else:
            group = self._groups[cfg]
            group.states = _set_lane(group.states, t.lane, svc.state)

    def _compact_tenant(self, t: _Tenant):
        """Post-chunk tombstone compaction, shadow-service style; a
        compaction that escalates capacity migrates the tenant."""
        group = self._groups[t.cfg]
        svc = self._shadow_service(t.cfg, _lane(group.states, t.lane))
        svc._maybe_compact()
        t.compaction_count += svc.compaction_count
        if svc.cfg != t.cfg:
            t.grow_count += svc.grow_count
            self._move_tenant(t, svc.cfg, svc._state)
        elif svc.compaction_count:
            group.states = _set_lane(group.states, t.lane, svc._state)

    # -------------------------------------------------------------- queries

    def same_scc_many(self, items):
        """Cross-tenant SameSCC: ``[(tid, u, v), ...]`` (arrays per
        tenant) -> ``{tid: (bool[n], gen)}`` -- per-tenant batches padded
        to a shared power-of-two Q and answered in one vmapped gather per
        capacity group, against committed lanes only."""
        return self._query_many(items, with_v=True)

    def community_of_many(self, items):
        """Cross-tenant blongsToCommunity: ``[(tid, u), ...]`` ->
        ``{tid: (int32[n], gen)}`` (sentinel ``n_vertices`` for absent or
        out-of-range ids)."""
        return self._query_many([(tid, u, None) for tid, u in items],
                                with_v=False)

    def _query_many(self, items, *, with_v: bool):
        out = {}
        with self._lock:
            by_cfg: Dict[gs.GraphConfig, list] = {}
            for tid, u, v in items:
                t = self._tenants[tid]
                by_cfg.setdefault(t.cfg, []).append(
                    (t, np.asarray(u, np.int64),
                     None if v is None else np.asarray(v, np.int64)))
            for cfg, rows in by_cfg.items():
                group = self._groups[cfg]
                qmax = max(max(r[1].shape[0], 1) for r in rows)
                q = 1 << (qmax - 1).bit_length()
                tb = self._pick_tenant_batch(len(rows))
                self._query_compiled.add(
                    ("same_scc" if with_v else "community_of",
                     tb, q, cfg))
                i = 0
                while i < len(rows):
                    sub = rows[i:i + tb]
                    i += tb
                    lanes = [r[0].lane for r in sub]
                    while len(lanes) < tb:
                        lanes.append(lanes[0])
                    states = jax.tree.map(
                        lambda a: a[jnp.asarray(np.asarray(lanes,
                                                           np.int32))],
                        group.states)
                    pu = np.zeros((tb, q), np.int32)
                    pv = np.zeros((tb, q), np.int32)
                    for r, (t, uu, vv) in enumerate(sub):
                        # clip to int32 range; true range masking below
                        pu[r, :uu.shape[0]] = np.clip(uu, -1,
                                                      cfg.n_vertices)
                        if vv is not None:
                            pv[r, :vv.shape[0]] = np.clip(
                                vv, -1, cfg.n_vertices)
                    if with_v:
                        res = np.asarray(_vmapped_same_scc(
                            states, jnp.asarray(pu), jnp.asarray(pv)))
                    else:
                        res = np.asarray(_vmapped_community_of(
                            states, jnp.asarray(pu)))
                    for r, (t, uu, vv) in enumerate(sub):
                        n = uu.shape[0]
                        vals = res[r, :n]
                        if with_v:
                            vals = vals & _ids_in_range(uu, cfg.n_vertices) \
                                & _ids_in_range(vv, cfg.n_vertices)
                        else:
                            vals = vals.copy()
                            vals[~_ids_in_range(uu, cfg.n_vertices)] = \
                                cfg.n_vertices
                        out[t.tid] = (vals, t.gen)
        return out

    # ---------------------------------------------------------------- stats

    def stats(self) -> dict:
        with self._lock:
            return {
                "tenants": len(self._tenants),
                "flushes": self.flush_count,
                "solo_replays": self.solo_replays,
                "compile_count": self.compile_count,
                "compile_bound": self.compile_bound,
                "query_shapes": len(self._query_compiled),
                "occupancy": self.occupancy(),
                "tenant_batches": list(self._tenant_batches),
            }
