"""Multi-tenant serving facade: per-tenant clients over one vmapped engine.

:class:`MultiTenantService` is the tenancy subsystem's public layer.  It
owns a :class:`~repro.tenancy.engine.TenantEngine` (stacked per-tenant
states, vmapped fused-scan dispatch) behind a
:class:`~repro.tenancy.queue.WorkQueue` (admission, coalescing,
backpressure) and exposes each tenant through the **unchanged typed
API**: :meth:`client` returns a plain :class:`repro.api.GraphClient`
whose service object is a :class:`_TenantSession` -- an
``SCCService``-shaped view of one tenant (``_apply_ops`` routes through
the admission queue; ``state``/``gen``/``wait_for_gen`` read that
tenant's committed lane).  Consistency levels therefore keep their
single-tenant meaning *per tenant*: a READ_YOUR_WRITES token is a floor
on that tenant's generation counter and nothing another tenant does can
advance or stall it.

Durability is per-tenant (``directory`` given): each tenant gets its own
``<directory>/tenants/<tid>`` store in exactly the PR-6
:class:`~repro.ckpt.durable.DurableService` layout -- boot snapshot +
write-ahead op log, appended under the flush with the tenant's pre-chunk
generation and rolled back if its lane fails.  That is what makes
**idle-tenant eviction** safe: ``evict`` snapshots the cold tenant,
compacts its lane out of the stacked arrays, and closes its log;
the next touch rehydrates it through ``DurableService.open`` (latest
snapshot + WAL tail, the snapshot's own decision knobs), bit-identical
to a tenant that never left.
"""
from __future__ import annotations

import os
import shutil
import threading
import time
from typing import Dict, Optional

import numpy as np

from repro.ckpt import checkpoint, oplog
from repro.ckpt.durable import DurableService, _cfg_meta, snap_dir, wal_dir
from repro.core import graph_state as gs
from repro.fault import errors as fault_errors
from repro.tenancy.engine import TenantEngine
from repro.tenancy.queue import TransferBufferPool, WorkQueue

__all__ = ["MultiTenantService", "_TenantSession"]


class _TenantHandle:
    __slots__ = ("tid", "resident", "directory", "wal", "last_used",
                 "evictions", "rehydrations", "wal_faults",
                 "parked_gen", "parked_cfg")

    def __init__(self, tid: str, directory: Optional[str]):
        self.tid = tid
        self.resident = True
        self.directory = directory
        self.wal: Optional[oplog.OpLogWriter] = None
        self.last_used = time.monotonic()
        self.evictions = 0
        self.rehydrations = 0
        self.wal_faults = 0
        self.parked_gen: Optional[int] = None     # while evicted
        self.parked_cfg: Optional[gs.GraphConfig] = None


class _TenantSession:
    """The ``SCCService`` surface of ONE tenant, as seen by
    :class:`repro.api.GraphClient` and :class:`repro.core.broker.QueryBroker`
    (which need exactly: ``_apply_ops``, ``state``, ``cfg``, ``gen``,
    ``wait_for_gen``, ``stats``)."""

    def __init__(self, service: "MultiTenantService", tid: str):
        self._mts = service
        self.tid = tid

    def _apply_ops(self, kind, u, v, *, session=None, seq=None):
        return self._mts._apply_ops(self.tid, kind, u, v,
                                    session=session, seq=seq)

    @property
    def cfg(self) -> gs.GraphConfig:
        return self._mts._tenant_cfg(self.tid)

    @property
    def state(self) -> gs.GraphState:
        """The tenant's committed lane (snapshot-consistent: lanes only
        move at flush commit, under the engine lock)."""
        return self._mts._tenant_state(self.tid)

    @property
    def gen(self) -> int:
        return self._mts.tenant_gen(self.tid)

    def wait_for_gen(self, gen: int, timeout: float | None = None) -> int:
        return self._mts._engine.wait_for_gen(self.tid, gen,
                                              timeout=timeout)

    def stats(self) -> dict:
        return self._mts.tenant_stats(self.tid)


class MultiTenantService:
    """Many independent graphs, one engine, one admission queue.

    ``cfg`` is the boot config every fresh tenant starts from (its own
    ``SCCService(cfg)`` twin); per-tenant capacity then walks the shared
    growth ladder independently.  The decision knobs
    (``buckets``/``grow_factor``/``max_edge_capacity``/
    ``compact_tomb_frac``) are engine-wide and match the single-tenant
    defaults, which is what the differential oracle test pins.
    """

    def __init__(self, cfg: gs.GraphConfig, *,
                 buckets=(64, 256, 1024),
                 scan_lengths=(1, 4, 16),
                 tenant_batches=(1, 2, 4, 8),
                 grow_factor: int = 2,
                 max_edge_capacity: int | None = None,
                 compact_tomb_frac: float = 0.25,
                 directory: str | None = None,
                 max_pending_ops: int = 8192,
                 coalesce_ops: int = 1024,
                 flush_deadline_s: float = 0.002,
                 idle_evict_s: float | None = None,
                 snapshot_keep: int = 3,
                 wal_sync_every: int = 1):
        self._boot_cfg = cfg
        self._dir = directory
        self._idle_evict_s = idle_evict_s
        self._snapshot_keep = snapshot_keep
        self._wal_sync_every = wal_sync_every
        self._engine = TenantEngine(
            buckets=buckets, scan_lengths=scan_lengths,
            tenant_batches=tenant_batches, grow_factor=grow_factor,
            max_edge_capacity=max_edge_capacity,
            compact_tomb_frac=compact_tomb_frac)
        self._queue = WorkQueue(
            self._flush_wave, max_pending_ops=max_pending_ops,
            coalesce_ops=coalesce_ops, flush_deadline_s=flush_deadline_s,
            pool=TransferBufferPool(buckets=tuple(buckets) + (4096,)))
        self._tenants: Dict[str, _TenantHandle] = {}
        self._lock = threading.RLock()
        self._next_tid = 0
        # (tid, session) -> (seq, ok, gen): idempotent-resubmit window.
        self._session_results: Dict[tuple, tuple] = {}

    # ------------------------------------------------------------ tenants

    @property
    def queue(self) -> WorkQueue:
        return self._queue

    @property
    def engine(self) -> TenantEngine:
        return self._engine

    def tenant_ids(self):
        with self._lock:
            return list(self._tenants)

    def create_tenant(self, tid: str | None = None) -> str:
        """Provision a tenant: a fresh empty graph at generation 0 (and,
        under a durable root, its own snapshot+WAL store)."""
        with self._lock:
            if tid is None:
                tid = f"t{self._next_tid}"
                self._next_tid += 1
            assert tid not in self._tenants, f"tenant {tid!r} exists"
            tenant_dir = None
            if self._dir is not None:
                tenant_dir = os.path.join(self._dir, "tenants", tid)
            h = _TenantHandle(tid, tenant_dir)
            state = gs.empty(self._boot_cfg)
            if tenant_dir is not None:
                os.makedirs(snap_dir(tenant_dir), exist_ok=True)
                os.makedirs(wal_dir(tenant_dir), exist_ok=True)
                checkpoint.save_graph_snapshot(
                    snap_dir(tenant_dir), state,
                    self._snapshot_meta(self._boot_cfg, 0),
                    keep=self._snapshot_keep)
                h.wal = oplog.OpLogWriter(
                    wal_dir(tenant_dir), sync_every=self._wal_sync_every,
                    start_gen=0)
            self._engine.create_tenant(tid, self._boot_cfg, state=state)
            self._tenants[tid] = h
            return tid

    def delete_tenant(self, tid: str):
        """Drop the tenant: lane, handle, and durable store."""
        self._queue.flush()
        with self._lock:
            h = self._tenants.pop(tid)
            if h.resident:
                self._engine.remove_tenant(tid)
            if h.wal is not None:
                h.wal.close()
            if h.directory is not None:
                shutil.rmtree(h.directory, ignore_errors=True)

    def session(self, tid: str) -> _TenantSession:
        with self._lock:
            assert tid in self._tenants, f"unknown tenant {tid!r}"
        return _TenantSession(self, tid)

    def client(self, tid: str, **client_kwargs):
        """A standard typed :class:`repro.api.GraphClient` bound to one
        tenant -- the existing API, unchanged, per tenant."""
        from repro.api import GraphClient
        return GraphClient(self.session(tid), **client_kwargs)

    # ----------------------------------------------------------- eviction

    def _snapshot_meta(self, cfg: gs.GraphConfig, gen: int) -> dict:
        # byte-compatible with DurableService._snapshot_meta so
        # DurableService.open / scratch_replay rehydrate an evicted
        # tenant with the engine's own decision knobs
        return {
            "gen": int(gen),
            "cfg": _cfg_meta(cfg),
            "service": {
                "buckets": list(self._engine._sched.buckets),
                "grow_factor": self._engine._grow_factor,
                "max_edge_capacity": self._engine._max_edge_capacity,
                "compact_tomb_frac": self._engine._compact_tomb_frac,
                "proactive_grow": False,
            },
        }

    def evict(self, tid: str):
        """Park a cold tenant on disk: snapshot its lane, compact it out
        of the stacked arrays, close its WAL.  Requires a durable root
        (otherwise the state would simply be lost)."""
        self._queue.flush()
        with self._lock:
            h = self._tenants[tid]
            if not h.resident:
                return
            assert h.directory is not None, (
                "eviction needs a durable root (directory=...): an "
                "evicted tenant is rebuilt from its snapshot + WAL")
            state, cfg, gen = self._engine.remove_tenant(tid)
            checkpoint.save_graph_snapshot(
                snap_dir(h.directory), state,
                self._snapshot_meta(cfg, gen), keep=self._snapshot_keep)
            h.wal.sync()
            h.wal.close()
            h.wal = None
            oplog.trim(wal_dir(h.directory), gen)
            h.resident = False
            h.parked_gen, h.parked_cfg = gen, cfg
            h.evictions += 1

    def evict_idle(self, max_idle_s: float | None = None) -> list:
        """Evict every resident tenant idle longer than ``max_idle_s``
        (default: the service's ``idle_evict_s`` policy knob)."""
        max_idle_s = self._idle_evict_s if max_idle_s is None \
            else max_idle_s
        if max_idle_s is None or self._dir is None:
            return []
        now = time.monotonic()
        with self._lock:
            cold = [tid for tid, h in self._tenants.items()
                    if h.resident and now - h.last_used > max_idle_s]
        for tid in cold:
            self.evict(tid)
        return cold

    def _ensure_resident(self, h: _TenantHandle):
        """Rehydrate an evicted tenant through the PR-6 recovery path:
        latest snapshot + WAL tail, under the snapshot's own decision
        knobs -- the same replay a crashed single-tenant service runs,
        so the rebuilt lane is bit-identical to one that never left."""
        if h.resident:
            return
        d = DurableService.open(h.directory, inflight_window=0,
                                donate=False)
        state, cfg, gen = d.state, d.cfg, d.gen
        d.close()
        self._engine.create_tenant(h.tid, cfg, state=state, gen=gen)
        h.wal = oplog.OpLogWriter(wal_dir(h.directory),
                                  sync_every=self._wal_sync_every,
                                  start_gen=gen)
        h.resident = True
        h.parked_gen = h.parked_cfg = None
        h.rehydrations += 1

    # ------------------------------------------------------------ updates

    def _apply_ops(self, tid: str, kind, u, v, *, session=None,
                   seq=None):
        """The per-tenant ``GraphClient`` update entry: admission-queued,
        flushed as part of a cross-tenant wave, acknowledged with the
        tenant's post-chunk generation.  ``(session, seq)`` is the
        client idempotency key (same contract as
        :meth:`repro.core.service.SCCService._apply_ops`): a re-submit
        of a session's last acknowledged chunk returns the recorded ack
        instead of re-queueing it."""
        key = None if session is None else (tid, session)
        with self._lock:
            h = self._tenants[tid]
            h.last_used = time.monotonic()
            self._ensure_resident(h)
            if key is not None:
                hit = self._session_results.get(key)
                if hit is not None and hit[0] == seq:
                    return hit[1], hit[2]
        ok, gen = self._queue.submit(tid, kind, u, v)
        if key is not None:
            with self._lock:
                self._session_results[key] = (seq, ok, gen)
                while len(self._session_results) > 4096:
                    self._session_results.pop(
                        next(iter(self._session_results)))
        return ok, gen

    def _flush_wave(self, requests):
        """WorkQueue callback: write-ahead log every tenant's chunk at
        its pre-chunk generation, apply the wave through the vmapped
        engine, roll back the WAL record of any lane that failed.

        Faults are a per-lane matter: a tenant whose WAL append fails
        (injected disk fault, full volume, fenced log) is dropped from
        the wave -- its chunk is neither applied nor acknowledged, and
        its submitter gets a typed retryable
        :class:`~repro.fault.errors.Unavailable` chained to the cause.
        The other tenants' lanes flush normally; one tenant's bad disk
        never fails a neighbour's write."""
        appended = []
        live = []
        errors: Dict[str, Exception] = {}
        with self._lock:
            for tid, kind, u, v in requests:
                h = self._tenants[tid]
                self._ensure_resident(h)    # evicted with a queued chunk
                h.last_used = time.monotonic()
                if h.wal is not None:
                    try:
                        h.wal.append(self._engine.tenant_gen(tid),
                                     kind, u, v)
                    except (OSError, fault_errors.Fenced) as e:
                        # append rolled itself back: nothing durable,
                        # so nothing may apply -- reject just this lane
                        h.wal_faults += 1
                        err = fault_errors.Unavailable(
                            f"tenant {tid!r} WAL append failed; chunk "
                            f"not applied",
                            retry_after=self._queue._flush_deadline_s
                            or 1e-3)
                        err.__cause__ = e
                        errors[tid] = err
                        continue
                    appended.append(h)
                live.append((tid, kind, u, v))
        results = self._engine.apply_chunks(live) if live else {}
        with self._lock:
            for h in appended:
                if isinstance(results.get(h.tid), Exception):
                    h.wal.rollback_last()
        results.update(errors)
        return results

    def flush(self):
        """Drain the admission queue synchronously."""
        self._queue.flush()

    # ------------------------------------------------------------ queries

    def _tenant_state(self, tid: str) -> gs.GraphState:
        with self._lock:
            self._ensure_resident(self._tenants[tid])
        return self._engine.tenant_state(tid)

    def _tenant_cfg(self, tid: str) -> gs.GraphConfig:
        with self._lock:
            h = self._tenants[tid]
            if not h.resident:
                return h.parked_cfg
        return self._engine.tenant_cfg(tid)

    def tenant_gen(self, tid: str) -> int:
        with self._lock:
            h = self._tenants[tid]
            if not h.resident:
                return h.parked_gen
        return self._engine.tenant_gen(tid)

    def same_scc_many(self, items):
        """Cross-tenant vmapped SameSCC (``[(tid, u, v), ...]``) -- the
        aggregate read path the bench drives; per-tenant reads normally
        go through each tenant's client/broker."""
        with self._lock:
            for tid, _, _ in items:
                self._ensure_resident(self._tenants[tid])
        return self._engine.same_scc_many(items)

    # -------------------------------------------------------------- stats

    def tenant_stats(self, tid: str) -> dict:
        with self._lock:
            h = self._tenants[tid]
            if h.resident:
                tel = self._engine.tenant_telemetry(tid)
            else:
                tel = {"gen": h.parked_gen,
                       "edge_capacity": h.parked_cfg.edge_capacity}
            tel.update(self._queue.latency_quantiles(tid))
            tel["resident"] = h.resident
            tel["evictions"] = h.evictions
            tel["rehydrations"] = h.rehydrations
            tel["wal_faults"] = h.wal_faults
            if h.wal is not None:
                tel["wal"] = h.wal.stats()
            return tel

    def stats(self) -> dict:
        """Aggregate serving telemetry: tenant census, engine registry /
        occupancy, and admission-queue depth/flush/latency counters."""
        with self._lock:
            resident = sum(1 for h in self._tenants.values()
                           if h.resident)
            per_tenant = {tid: self.tenant_stats(tid)
                          for tid in self._tenants}
        return {
            "tenants": {"total": len(per_tenant), "resident": resident,
                        "evicted": len(per_tenant) - resident},
            "engine": self._engine.stats(),
            "queue": self._queue.stats(),
            "per_tenant": per_tenant,
        }

    def close(self):
        self._queue.flush()
        with self._lock:
            for h in self._tenants.values():
                if h.wal is not None:
                    h.wal.sync()
                    h.wal.close()
                    h.wal = None
