"""Multi-tenant graph serving: many per-tenant graphs behind one vmapped
engine and one admission queue.

Layers (see ``docs/ARCHITECTURE.md``):

* :mod:`repro.tenancy.engine` -- :class:`TenantEngine`: per-tenant
  ``GraphState`` lanes stacked per capacity class, the fused 5-phase
  scan step vmapped over the tenant axis, per-lane overflow isolation
  with solo grow-and-replay, and the ``(tenant_batch, scan_len,
  bucket)``-keyed compiled-entry registry with an asserted bound.
* :mod:`repro.tenancy.queue` -- :class:`WorkQueue` (bounded admission,
  deadline/size-triggered cross-tenant coalescing, reject-with-
  retry-after backpressure) and :class:`TransferBufferPool` (pooled
  host buffers: steady-state submits allocate nothing).
* :mod:`repro.tenancy.multi_service` -- :class:`MultiTenantService`:
  per-tenant :class:`repro.api.GraphClient` sessions over the unchanged
  typed API, per-tenant generation counters / stats / durability (WAL +
  snapshots per tenant), and idle-tenant eviction with bit-identical
  WAL rehydration.
"""
from repro.tenancy.engine import TenantEngine
from repro.tenancy.multi_service import MultiTenantService
from repro.tenancy.queue import QueueFull, TransferBufferPool, WorkQueue

__all__ = ["TenantEngine", "MultiTenantService", "WorkQueue",
           "TransferBufferPool", "QueueFull"]
